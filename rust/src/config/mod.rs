//! Configuration: model hyper-parameters (mirrors `python/compile/configs.py`
//! and is re-hydrated from `artifacts/manifest.json`), engine settings, and
//! the paper's three accelerator profiles (Fig. 4 / Table 4).

use crate::json::Json;
use anyhow::{Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,      // h
    pub n_kv_groups: usize,  // g
    pub head_dim: usize,     // d
    pub n_layers: usize,     // L
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let gu = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("config field `{k}`"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .context("config name")?
                .to_string(),
            vocab: gu("vocab")?,
            d_model: gu("d_model")?,
            n_heads: gu("n_heads")?,
            n_kv_groups: gu("n_kv_groups")?,
            head_dim: gu("head_dim")?,
            n_layers: gu("n_layers")?,
            d_ff: gu("d_ff")?,
            max_seq: gu("max_seq")?,
            rope_theta: j
                .get("rope_theta")
                .and_then(Json::as_f64)
                .unwrap_or(10000.0),
        })
    }

    /// Merged key/value width g*d.
    pub fn kv_dim(&self) -> usize {
        self.n_kv_groups * self.head_dim
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// GQA KV-cache floats per token per layer.
    pub fn kv_per_token(&self) -> usize {
        2 * self.kv_dim()
    }

    /// MLA KV-cache floats per token per layer at latent rank r.
    pub fn mla_kv_per_token(&self, r: usize) -> usize {
        r + self.head_dim
    }

    /// Paper's "-X%" KV compression at rank r.
    pub fn compression(&self, r: usize) -> f64 {
        1.0 - self.mla_kv_per_token(r) as f64 / self.kv_per_token() as f64
    }

    /// Approximate parameter count of the GQA model.
    pub fn n_params(&self) -> usize {
        let (dm, f, l, v) = (self.d_model, self.d_ff, self.n_layers, self.vocab);
        let attn = dm * self.q_dim() + 2 * dm * self.kv_dim() + self.q_dim() * dm;
        let mlp = 3 * dm * f;
        2 * v * dm + l * (attn + mlp + 2 * dm) + dm
    }
}

/// Which scheduling policy the engine runs (see `coordinator::scheduler`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// Admit whenever a slot is free (the original engine behaviour).
    #[default]
    AdmitFirst,
    /// Drain the active batch before admitting new requests.
    DecodeFirst,
    /// Admit only once `min_free` slots are free (or nothing is active).
    Hybrid { min_free: usize },
    /// Chunked, decode-overlapped prefill: admit eagerly and feed prompts
    /// into the cache at most `chunk_tokens` per iteration, decoding in
    /// the same iteration (see `coordinator::scheduler::Chunked`).
    Chunked { chunk_tokens: usize },
    /// Speculative decoding: admit like `admit-first`, but decode steps
    /// run the draft-propose / target-verify loop at most `k` tokens per
    /// slot per iteration (see `coordinator::scheduler::Speculative` and
    /// `Engine::speculative_decode_step`). Requires a draft backend
    /// (`draft=SPEC` in the `--model` grammar) and a target backend with
    /// `ExecBackend::supports_verify`.
    Speculative { k: usize },
}

/// Default prefill-chunk token budget per iteration for `chunked`.
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

/// Default candidate tokens per slot per iteration for `speculative`.
pub const DEFAULT_SPEC_K: usize = 4;

impl PolicyKind {
    /// Parse `admit-first` / `decode-first` / `hybrid[:N]` / `chunked[:N]`
    /// / `speculative[:K]`.
    pub fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "admit-first" => Ok(PolicyKind::AdmitFirst),
            "decode-first" => Ok(PolicyKind::DecodeFirst),
            "hybrid" => Ok(PolicyKind::Hybrid { min_free: 2 }),
            "chunked" => Ok(PolicyKind::Chunked { chunk_tokens: DEFAULT_PREFILL_CHUNK }),
            "speculative" => Ok(PolicyKind::Speculative { k: DEFAULT_SPEC_K }),
            other => {
                if let Some(n) = other.strip_prefix("hybrid:") {
                    Ok(PolicyKind::Hybrid {
                        min_free: n
                            .parse()
                            .ok()
                            .with_context(|| format!("bad hybrid threshold `{n}`"))?,
                    })
                } else if let Some(n) = other.strip_prefix("chunked:") {
                    Ok(PolicyKind::Chunked {
                        chunk_tokens: n
                            .parse::<usize>()
                            .ok()
                            .filter(|&c| c > 0)
                            .with_context(|| format!("bad chunk size `{n}`"))?,
                    })
                } else if let Some(n) = other.strip_prefix("speculative:") {
                    Ok(PolicyKind::Speculative {
                        k: n
                            .parse::<usize>()
                            .ok()
                            .filter(|&k| k > 0)
                            .with_context(|| format!("bad speculation depth `{n}`"))?,
                    })
                } else {
                    anyhow::bail!(
                        "unknown policy `{other}` \
                         (admit-first|decode-first|hybrid[:N]|chunked[:N]|speculative[:K])"
                    )
                }
            }
        }
    }
}

/// Which KV-cache store the engine allocates (see `kvcache`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheKind {
    /// One worst-case `capacity`-length row per slot (the seed layout;
    /// what the XLA decode artifacts operate on).
    Fixed,
    /// Block-granular paged allocation: `block_size`-token blocks over a
    /// shared pool. `n_blocks` of `None` sizes the pool to the fixed
    /// store's worst-case byte budget.
    Paged { block_size: usize, n_blocks: Option<usize> },
}

impl Default for CacheKind {
    fn default() -> Self {
        CacheKind::Fixed
    }
}

/// Default tokens per block for the paged cache.
pub const DEFAULT_BLOCK_SIZE: usize = 16;

impl CacheKind {
    /// Parse `fixed` / `paged` / `paged:B` (B = block size in tokens).
    pub fn parse(s: &str) -> Result<CacheKind> {
        match s {
            "fixed" => Ok(CacheKind::Fixed),
            "paged" => Ok(CacheKind::Paged {
                block_size: DEFAULT_BLOCK_SIZE,
                n_blocks: None,
            }),
            other => match other.strip_prefix("paged:") {
                Some(b) => {
                    let block_size: usize = b
                        .parse::<usize>()
                        .ok()
                        .filter(|&n| n > 0)
                        .with_context(|| format!("bad block size `{b}`"))?;
                    Ok(CacheKind::Paged { block_size, n_blocks: None })
                }
                None => {
                    anyhow::bail!("unknown cache kind `{other}` (fixed|paged[:B])")
                }
            },
        }
    }
}

/// Keys a `--model name=SPEC` override list may set — exactly the
/// engine-shaping CLI flags, so one grammar serves both spellings.
pub const MODEL_SPEC_KEYS: &[&str] = &[
    "arch",
    "layout", // alias for arch (the README SPEC spelling)
    "rank",
    "backend",
    "policy",
    "prefill-chunk",
    "cache",
    "block-size",
    "cache-blocks",
    "prefix-cache",
    "batch",
    "capacity",
    "seed",
    "ckpt",
    "weight",
    "overlap",
    "draft",
    "quant",
];

/// One `--model name=SPEC` CLI entry: a named engine whose SPEC is a
/// comma-separated `key=value` list reusing the existing engine flags,
/// e.g. `mla=layout=mla,cache=paged,policy=chunked:8,prefix-cache=on`.
/// A bare `--model name` (no `=`) inherits every setting from the
/// top-level flags. Keys are validated here; values are parsed by the
/// same code that parses the corresponding flag, so the two spellings
/// can never drift.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    /// Flag overrides in SPEC order (later wins on duplicates).
    pub overrides: Vec<(String, String)>,
}

impl ModelSpec {
    pub fn parse(s: &str) -> Result<ModelSpec> {
        let (name, spec) = match s.split_once('=') {
            Some((n, rest)) => (n, Some(rest)),
            None => (s, None),
        };
        if name.is_empty() {
            anyhow::bail!("--model needs a name (`--model name[=key=value,...]`)");
        }
        let mut overrides = Vec::new();
        if let Some(spec) = spec {
            for kv in spec.split(',') {
                let (k, v) = kv.split_once('=').with_context(|| {
                    format!("bad --model override `{kv}` (want key=value)")
                })?;
                if !MODEL_SPEC_KEYS.contains(&k) {
                    anyhow::bail!(
                        "unknown --model key `{k}` (valid: {})",
                        MODEL_SPEC_KEYS.join(", ")
                    );
                }
                if v.is_empty() {
                    anyhow::bail!("empty value for --model key `{k}`");
                }
                overrides.push((k.to_string(), v.to_string()));
            }
        }
        Ok(ModelSpec { name: name.to_string(), overrides })
    }
}

/// Engine/serving settings.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Decode batch width (must match an exported decode artifact).
    pub batch: usize,
    /// Max new tokens per request by default.
    pub max_new_tokens: usize,
    /// Sampling temperature (0 = greedy).
    pub temperature: f32,
    pub seed: u64,
    /// Scheduling policy (admission vs decode per iteration).
    pub policy: PolicyKind,
    /// KV-cache store (fixed slot rows vs paged blocks).
    pub cache: CacheKind,
    /// Cross-sequence prefix sharing over the paged store
    /// (`--prefix-cache on`): same-prefix prompts share cached blocks
    /// copy-on-write instead of each holding a private copy. Requires
    /// `CacheKind::Paged`; rejected at engine construction otherwise.
    pub prefix_cache: bool,
    /// Fair-share weight in the multi-engine sweep (`weight=K` in a
    /// `--model` SPEC): a weight-K engine gets K step opportunities per
    /// sweep / worker iteration. Clamped to >= 1 at use sites.
    pub weight: usize,
    /// Dual-stream execution (`--overlap on` / `overlap=on`): run the
    /// prefill chunk and the decode batch of one iteration concurrently
    /// when the backend signs the contract
    /// (`ExecBackend::supports_overlap`). Off by default; completions
    /// are bit-identical either way.
    pub overlap: bool,
    /// Lossy block codec for the paged KV pool (`--kv-quant` /
    /// `quant=` in a `--model` SPEC): encoded blocks shrink
    /// bytes-per-token, so the same `--cache-blocks` byte budget admits
    /// more sequences. Requires `CacheKind::Paged`; rejected at engine
    /// construction otherwise. `Off` by default.
    pub kv_quant: crate::kvcache::QuantKind,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: 8,
            max_new_tokens: 64,
            temperature: 0.0,
            seed: 0,
            policy: PolicyKind::AdmitFirst,
            cache: CacheKind::Fixed,
            prefix_cache: false,
            weight: 1,
            overlap: false,
            kv_quant: crate::kvcache::QuantKind::Off,
        }
    }
}

/// Serving SLO for goodput accounting (the workload harness and its
/// report): a completion counts toward goodput iff every bound that is
/// set holds. `None` bounds are unbounded, so the zero-value spec
/// accepts everything — goodput then equals plain throughput.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloSpec {
    /// Max time-to-first-token, milliseconds (`--slo-ttft-ms`; 0 on the
    /// CLI disables the bound).
    pub ttft_ms: Option<f64>,
    /// Max mean per-token decode time, milliseconds (`--slo-tpot-ms`).
    pub tpot_ms: Option<f64>,
}

impl SloSpec {
    /// Did a completion with these (seconds-denominated) timings meet
    /// the SLO?
    pub fn met(&self, ttft_s: f64, tpot_s: f64) -> bool {
        self.ttft_ms.map_or(true, |b| ttft_s * 1e3 <= b)
            && self.tpot_ms.map_or(true, |b| tpot_s * 1e3 <= b)
    }

    /// Report spelling, e.g. `ttft<=250ms,tpot<=20ms` (`none` when
    /// every bound is unbounded).
    pub fn name(&self) -> String {
        let mut parts = Vec::new();
        if let Some(b) = self.ttft_ms {
            parts.push(format!("ttft<={b}ms"));
        }
        if let Some(b) = self.tpot_ms {
            parts.push(format!("tpot<={b}ms"));
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }
}

/// `transmla eval` driver options (the quality harness — see
/// [`crate::qeval`]): how hard to drive the server and which model is
/// the A/B reference.
#[derive(Clone, Debug)]
pub struct EvalOpts {
    /// Bounded in-flight request concurrency across all (model × row)
    /// jobs (`--concurrency`).
    pub concurrency: usize,
    /// New-token budget per row (`--max-new`).
    pub max_new: usize,
    /// Baseline model name for per-model deltas (`--baseline`).
    pub baseline: Option<String>,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts { concurrency: 8, max_new: 16, baseline: None }
    }
}

/// Analytical accelerator profile (paper Sec. 5.4: three consumer GPUs).
#[derive(Clone, Debug)]
pub struct HardwareProfile {
    pub name: String,
    pub tflops: f64,      // peak FP16 compute
    pub mem_gb: f64,      // HBM capacity
    pub bw_gbs: f64,      // HBM bandwidth GB/s
}

impl HardwareProfile {
    /// The paper's three platforms. Bandwidths are the public figures for
    /// the matching consumer parts (RTX 4090-class 24GB, A100-40G-class,
    /// and a 64GB 320-TFLOPS accelerator).
    pub fn paper_profiles() -> Vec<HardwareProfile> {
        vec![
            HardwareProfile {
                name: "165.2TF|24GB".into(),
                tflops: 165.2,
                mem_gb: 24.0,
                bw_gbs: 1008.0,
            },
            HardwareProfile {
                name: "312TF|40GB".into(),
                tflops: 312.0,
                mem_gb: 40.0,
                bw_gbs: 1555.0,
            },
            HardwareProfile {
                name: "320TF|64GB".into(),
                tflops: 320.0,
                mem_gb: 64.0,
                bw_gbs: 1200.0,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slo_spec_bounds_and_name() {
        let none = SloSpec::default();
        assert!(none.met(10.0, 10.0), "unbounded SLO accepts everything");
        assert_eq!(none.name(), "none");
        let slo = SloSpec { ttft_ms: Some(250.0), tpot_ms: Some(20.0) };
        assert!(slo.met(0.250, 0.020), "bounds are inclusive");
        assert!(!slo.met(0.251, 0.010), "ttft bound enforced");
        assert!(!slo.met(0.100, 0.021), "tpot bound enforced");
        assert_eq!(slo.name(), "ttft<=250ms,tpot<=20ms");
        assert_eq!(SloSpec { ttft_ms: Some(100.0), tpot_ms: None }.name(), "ttft<=100ms");
    }

    #[test]
    fn eval_opts_defaults() {
        let o = EvalOpts::default();
        assert_eq!((o.concurrency, o.max_new), (8, 16));
        assert!(o.baseline.is_none());
    }

    #[test]
    fn parse_config_json() {
        let j = Json::parse(
            r#"{"name":"llama2tiny","vocab":256,"d_model":256,"n_heads":8,
               "n_kv_groups":8,"head_dim":32,"n_layers":4,"d_ff":768,
               "max_seq":512,"rope_theta":10000.0}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.kv_per_token(), 512);
        assert_eq!(c.kv_dim(), 256);
        assert!((c.compression(4) - 0.9297).abs() < 1e-3);
        assert!((c.compression(128) - 0.6875).abs() < 1e-9);
        assert!((c.compression(32) - 0.875).abs() < 1e-9);
    }

    #[test]
    fn param_count_sane() {
        let j = Json::parse(
            r#"{"name":"x","vocab":256,"d_model":256,"n_heads":8,
               "n_kv_groups":8,"head_dim":32,"n_layers":4,"d_ff":768,
               "max_seq":512}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        let n = c.n_params();
        assert!(n > 3_000_000 && n < 6_000_000, "{n}");
    }

    #[test]
    fn policy_kind_parses() {
        assert_eq!(PolicyKind::parse("admit-first").unwrap(), PolicyKind::AdmitFirst);
        assert_eq!(PolicyKind::parse("decode-first").unwrap(), PolicyKind::DecodeFirst);
        assert_eq!(
            PolicyKind::parse("hybrid:3").unwrap(),
            PolicyKind::Hybrid { min_free: 3 }
        );
        assert_eq!(
            PolicyKind::parse("hybrid").unwrap(),
            PolicyKind::Hybrid { min_free: 2 }
        );
        assert_eq!(
            PolicyKind::parse("chunked:8").unwrap(),
            PolicyKind::Chunked { chunk_tokens: 8 }
        );
        assert_eq!(
            PolicyKind::parse("chunked").unwrap(),
            PolicyKind::Chunked { chunk_tokens: DEFAULT_PREFILL_CHUNK }
        );
        assert_eq!(
            PolicyKind::parse("speculative:2").unwrap(),
            PolicyKind::Speculative { k: 2 }
        );
        assert_eq!(
            PolicyKind::parse("speculative").unwrap(),
            PolicyKind::Speculative { k: DEFAULT_SPEC_K }
        );
        assert!(PolicyKind::parse("nope").is_err());
        assert!(PolicyKind::parse("hybrid:x").is_err());
        assert!(PolicyKind::parse("chunked:0").is_err());
        assert!(PolicyKind::parse("chunked:x").is_err());
        assert!(PolicyKind::parse("speculative:0").is_err());
        assert!(PolicyKind::parse("speculative:x").is_err());
        assert_eq!(EngineConfig::default().policy, PolicyKind::AdmitFirst);
    }

    #[test]
    fn cache_kind_parses() {
        assert_eq!(CacheKind::parse("fixed").unwrap(), CacheKind::Fixed);
        assert_eq!(
            CacheKind::parse("paged").unwrap(),
            CacheKind::Paged { block_size: DEFAULT_BLOCK_SIZE, n_blocks: None }
        );
        assert_eq!(
            CacheKind::parse("paged:32").unwrap(),
            CacheKind::Paged { block_size: 32, n_blocks: None }
        );
        assert!(CacheKind::parse("paged:0").is_err());
        assert!(CacheKind::parse("paged:x").is_err());
        assert!(CacheKind::parse("nope").is_err());
        assert_eq!(EngineConfig::default().cache, CacheKind::Fixed);
    }

    #[test]
    fn model_spec_parses_the_cli_grammar() {
        let m = ModelSpec::parse(
            "mla=layout=mla,cache=paged,policy=chunked:8,prefix-cache=on",
        )
        .unwrap();
        assert_eq!(m.name, "mla");
        assert_eq!(
            m.overrides,
            vec![
                ("layout".to_string(), "mla".to_string()),
                ("cache".to_string(), "paged".to_string()),
                ("policy".to_string(), "chunked:8".to_string()),
                ("prefix-cache".to_string(), "on".to_string()),
            ]
        );
        // A bare name inherits everything from the top-level flags.
        let bare = ModelSpec::parse("gqa-base").unwrap();
        assert_eq!(bare.name, "gqa-base");
        assert!(bare.overrides.is_empty());
        // Values may themselves contain `=`-free structure like `:`.
        let r = ModelSpec::parse("m=policy=hybrid:3,rank=16").unwrap();
        assert_eq!(r.overrides[1], ("rank".to_string(), "16".to_string()));
        assert!(ModelSpec::parse("=cache=paged").is_err(), "empty name");
        assert!(ModelSpec::parse("m=cache").is_err(), "key without value");
        assert!(ModelSpec::parse("m=warp=9").is_err(), "unknown key");
        assert!(ModelSpec::parse("m=cache=").is_err(), "empty value");
        // PR 6 keys: weighted fair shares + dual-stream overlap.
        let w = ModelSpec::parse("heavy=weight=2,overlap=on").unwrap();
        assert_eq!(
            w.overrides,
            vec![
                ("weight".to_string(), "2".to_string()),
                ("overlap".to_string(), "on".to_string()),
            ]
        );
        // PR 7 key: a draft model spec for speculative decoding.
        let s = ModelSpec::parse("big=policy=speculative:4,draft=mla:2").unwrap();
        assert_eq!(
            s.overrides,
            vec![
                ("policy".to_string(), "speculative:4".to_string()),
                ("draft".to_string(), "mla:2".to_string()),
            ]
        );
        // PR 8 key: the KV block codec.
        let q = ModelSpec::parse("q=cache=paged,quant=int8").unwrap();
        assert_eq!(
            q.overrides,
            vec![
                ("cache".to_string(), "paged".to_string()),
                ("quant".to_string(), "int8".to_string()),
            ]
        );
    }

    #[test]
    fn hardware_profiles_present() {
        let hw = HardwareProfile::paper_profiles();
        assert_eq!(hw.len(), 3);
        assert!(hw[0].mem_gb < hw[1].mem_gb);
    }
}
