//! XLA execution backend: the AOT-artifact path.
//!
//! [`ModelBundle`] owns the compiled prefill/decode pair and the
//! device-resident weights for one model; [`XlaBackend`] adapts it to the
//! [`ExecBackend`] contract the engine consumes. The artifact ABI
//! (manifest names, argument order, tuple outputs) is unchanged from the
//! original fused engine — nothing on the `python/compile` side moves.

use super::{Arch, BackendSpec, CacheStore, ExecBackend, PrefillOut};
use crate::kvcache::CacheLayout;
use crate::model::Params;
use crate::runtime::{Exec, Runtime, Value};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// The compiled artifact pair + device-resident weights for one model.
pub struct ModelBundle {
    pub arch: Arch,
    pub cfg_name: String,
    pub prefill: Arc<Exec>,
    pub decode: Arc<Exec>,
    pub params: Params,
    param_bufs: Vec<xla::PjRtBuffer>,
    /// Host literals backing `param_bufs` — kept alive for the bundle's
    /// lifetime because PJRT host->device transfers are asynchronous.
    _param_lits: Vec<xla::Literal>,
    pub layout: CacheLayout,
    pub batch: usize,
    pub prefill_batch: usize,
    pub capacity: usize,
}

impl ModelBundle {
    pub fn load(
        rt: &Runtime,
        cfg_name: &str,
        arch: Arch,
        batch: usize,
        params: Params,
    ) -> Result<ModelBundle> {
        let (prefill_name, decode_name) = match arch {
            Arch::Gqa => (
                format!("{cfg_name}_gqa_prefill"),
                format!("{cfg_name}_gqa_decode_b{batch}"),
            ),
            Arch::Mla { rank } => (
                format!("{cfg_name}_mla_prefill_r{rank}"),
                format!("{cfg_name}_mla_decode_r{rank}_b{batch}"),
            ),
        };
        Self::load_named(rt, cfg_name, arch, batch, params, &prefill_name, &decode_name)
    }

    /// Load with explicit artifact names (context-length variants carry a
    /// `_t{T}` suffix on the decode artifact).
    pub fn load_named(
        rt: &Runtime,
        cfg_name: &str,
        arch: Arch,
        batch: usize,
        params: Params,
        prefill_name: &str,
        decode_name: &str,
    ) -> Result<ModelBundle> {
        let prefill = rt.load(prefill_name)?;
        let decode = rt.load(decode_name)?;
        params.check_against(&decode.spec)?;
        let cfg = &decode.spec.config;
        let layout = match arch {
            Arch::Gqa => CacheLayout::Gqa { g: cfg.n_kv_groups, d: cfg.head_dim },
            Arch::Mla { rank } => CacheLayout::Mla { r: rank, dr: cfg.head_dim },
        };
        let mut param_bufs = Vec::new();
        let mut _param_lits = Vec::new();
        for v in params.values() {
            let (buf, lit) = prefill.upload_owned(&v)?;
            param_bufs.push(buf);
            _param_lits.push(lit);
        }
        let prefill_batch = prefill.spec.batch.context("prefill batch")?;
        // Cache capacity comes from the decode artifact's cache input
        // shape [L, B, T, ...] (context-length variants differ from the
        // config's max_seq).
        let n = decode.spec.params.len();
        let capacity = decode.spec.inputs[n + 2].shape[2];
        Ok(ModelBundle {
            arch,
            cfg_name: cfg_name.to_string(),
            prefill,
            decode,
            params,
            param_bufs,
            _param_lits,
            layout,
            batch,
            prefill_batch,
            capacity,
        })
    }

    pub fn n_layers(&self) -> usize {
        self.decode.spec.config.n_layers
    }

    pub fn vocab(&self) -> usize {
        self.decode.spec.config.vocab
    }

    /// Sequence length of the prefill entry point.
    pub fn prefill_seq(&self) -> usize {
        self.prefill.spec.inputs.last().map(|a| a.shape[1]).unwrap_or(0)
    }
}

/// `ExecBackend` over a [`ModelBundle`] (PJRT execution).
pub struct XlaBackend {
    bundle: ModelBundle,
    spec: BackendSpec,
}

impl XlaBackend {
    pub fn new(bundle: ModelBundle) -> XlaBackend {
        let spec = BackendSpec {
            arch: bundle.arch,
            name: bundle.cfg_name.clone(),
            layout: bundle.layout,
            n_layers: bundle.n_layers(),
            vocab: bundle.vocab(),
            batch: bundle.batch,
            prefill_batch: bundle.prefill_batch,
            prefill_seq: bundle.prefill_seq(),
            capacity: bundle.capacity,
        };
        XlaBackend { bundle, spec }
    }

    pub fn bundle(&self) -> &ModelBundle {
        &self.bundle
    }
}

impl ExecBackend for XlaBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn prefill(&mut self, tokens: &[i32], rows: usize) -> Result<PrefillOut> {
        let (bp, t) = (self.spec.prefill_batch, self.spec.prefill_seq);
        if rows == 0 || rows > bp {
            bail!("xla prefill rows {rows} out of range (prefill_batch {bp})");
        }
        if tokens.len() != rows * t {
            bail!(
                "xla prefill wants {} tokens for {rows} rows, got {}",
                rows * t,
                tokens.len()
            );
        }
        // The AOT artifact's input shape is fixed at `[Bp, T]`: pad the
        // admitted rows back up to the full matrix (the sim backend
        // instead sizes its buffers to `rows`). Outputs keep the full
        // `Bp` rows dim; callers index rows < `rows`.
        let mut padded = tokens.to_vec();
        padded.resize(bp * t, 0);
        let outs = self.bundle.prefill.run_b(
            &self.bundle.param_bufs,
            &[Value::i32_mat(padded, &[bp, t])],
        )?;
        let mut it = outs.into_iter();
        let logits = it.next().context("prefill logits")?;
        let caches: Vec<Tensor> = it.collect();
        Ok(PrefillOut { logits, caches })
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        slot: usize,
        start_pos: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        let (t, v) = (self.spec.prefill_seq, self.spec.vocab);
        let end = tokens.len();
        if start_pos >= end {
            bail!("xla prefill_chunk: empty chunk ({start_pos}..{end})");
        }
        if end > t {
            bail!("xla prefill_chunk: {end} tokens exceed prefill_seq {t}");
        }
        // The AOT ABI has no per-position resume entry, so chunking the
        // XLA path recomputes the whole prefix through the fixed-shape
        // prefill artifact and re-splices positions 0..end — O(end)
        // recompute per chunk traded for decode overlap, with the
        // artifacts themselves untouched. (This also heals the pos-0
        // rows the decode artifact writes for inactive slots.)
        let mut row0 = vec![0i32; t];
        row0[..end].copy_from_slice(tokens);
        let out = self.prefill(&row0, 1)?;
        cache.splice_from(&out.caches, 0, slot, end)?;
        let off = (end - 1) * v;
        let mut row = Tensor::zeros(&[v]);
        row.data.copy_from_slice(&out.logits.data[off..off + v]);
        Ok(row)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        _active: &[bool],
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        // The AOT decode artifacts compute over the fixed padded cache
        // shape [L, B, T, ...]; the paged pool has no artifact ABI (yet).
        let kv = match cache.as_fixed_mut() {
            Some(kv) => kv,
            None => bail!("xla backend requires the fixed slot cache (--cache fixed)"),
        };
        // The cache tensors go in as the trailing inputs and come back
        // as the trailing outputs, written in place — no per-step
        // reallocation or full-buffer store round-trip.
        let (c0, c1) = kv.bufs.split_at_mut(1);
        let mut outs = self.bundle.decode.run_b_mixed_io(
            &self.bundle.param_bufs,
            &[
                Value::i32_vec(tokens.to_vec()),
                Value::i32_vec(pos.to_vec()),
            ],
            &mut [&mut c0[0], &mut c1[0]],
        )?;
        if outs.len() != 1 {
            bail!("decode artifact returned {} leading outputs, want 1", outs.len());
        }
        Ok(outs.remove(0))
    }
}
