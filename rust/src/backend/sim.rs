//! Hermetic simulation backend: a deterministic pure-Rust model that
//! honours the artifact contract exactly — per-position prefill logits,
//! position-masked decode, and slot caches in either `CacheLayout` — with
//! no artifacts, no PJRT, and no Python.
//!
//! The "model" is a rolling 64-bit hash over the token prefix. The state
//! after consuming `tokens[0..=p]` is written into the cache row at
//! position `p` (as ten exact base-100 digits in the leading inner dims;
//! the remaining dims carry derived filler so cache traffic is
//! layout-faithful). Decode reads the state at `pos-1` from the cache,
//! mixes in the new token, writes position `pos`, and emits logits that
//! are a pure function of the new state. Consequences, by construction:
//!
//!   * decode reproduces prefill logits at every position (the same
//!     invariant `integration_runtime` proves for the HLO path);
//!   * sequences are slot-isolated and batch-invariant (state lives only
//!     in the slot's own cache row);
//!   * everything is bit-deterministic for a given seed.
//!
//! This is what makes `cargo test` meaningful on a bare checkout: the
//! full admit → decode → complete engine loop, the scheduler policies,
//! and the server protocol all run against this backend.

use super::{Arch, BackendSpec, CacheStore, ExecBackend, PrefillOut};
use crate::kvcache::{CacheLayout, KvCache, PagedKvCache};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Geometry of a simulated model.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub arch: Arch,
    pub layout: CacheLayout,
    pub vocab: usize,
    pub n_layers: usize,
    pub batch: usize,
    pub prefill_batch: usize,
    pub prefill_seq: usize,
    pub capacity: usize,
    pub seed: u64,
}

impl SimConfig {
    /// A small GQA model: byte vocab, 2 layers, 64-token context.
    pub fn gqa(batch: usize) -> SimConfig {
        SimConfig {
            arch: Arch::Gqa,
            layout: CacheLayout::Gqa { g: 2, d: 8 },
            vocab: 256,
            n_layers: 2,
            batch,
            prefill_batch: batch,
            prefill_seq: 64,
            capacity: 64,
            seed: 0,
        }
    }

    /// The MLA-latent counterpart at latent rank `r`.
    pub fn mla(batch: usize, r: usize) -> SimConfig {
        SimConfig {
            arch: Arch::Mla { rank: r },
            layout: CacheLayout::Mla { r, dr: 8 },
            ..SimConfig::gqa(batch)
        }
    }
}

/// Number of leading inner dims that carry the exact prefix state, one
/// base-100 digit (0..=99) per dim: `2^64 < 100^10`, so ten digits hold
/// any u64 exactly. Base 100 (not 2^16) is deliberate: a per-row int8
/// codec over the paged pool has scale `max|row| / 127 <= 99/127 < 1`,
/// so its worst-case error `scale/2 < 0.5` and the round-to-nearest
/// read in [`state_of_rows`] recovers every digit exactly — quantized
/// greedy completions stay bit-identical to fp32 by construction.
const STATE_CHUNKS: usize = 10;

pub struct SimBackend {
    spec: BackendSpec,
    base_state: u64,
}

impl SimBackend {
    pub fn new(cfg: SimConfig) -> Result<SimBackend> {
        let (i0, i1) = inner_dims(cfg.layout);
        if i0 + i1 < STATE_CHUNKS {
            bail!(
                "sim layout {:?} too narrow: needs >= {STATE_CHUNKS} inner dims",
                cfg.layout
            );
        }
        if cfg.batch == 0 || cfg.prefill_batch == 0 || cfg.capacity < 2 {
            bail!("degenerate sim geometry {cfg:?}");
        }
        let base_state = mix(cfg.seed, 0x0BAD_5EED);
        Ok(SimBackend {
            spec: BackendSpec {
                arch: cfg.arch,
                name: "sim".to_string(),
                layout: cfg.layout,
                n_layers: cfg.n_layers,
                vocab: cfg.vocab,
                batch: cfg.batch,
                prefill_batch: cfg.prefill_batch,
                prefill_seq: cfg.prefill_seq,
                capacity: cfg.capacity,
            },
            base_state,
        })
    }

    /// Default GQA sim model with `batch` decode slots.
    pub fn gqa(batch: usize) -> SimBackend {
        SimBackend::new(SimConfig::gqa(batch)).expect("default gqa sim config")
    }

    /// Default MLA sim model at latent rank `r`.
    pub fn mla(batch: usize, r: usize) -> SimBackend {
        SimBackend::new(SimConfig::mla(batch, r)).expect("default mla sim config")
    }

    fn logits_row(&self, state: u64, out: &mut [f32]) {
        for (v, slot) in out.iter_mut().enumerate() {
            *slot = unit(mix(state, 0xA5A5_0000 ^ v as u64)) * 4.0 - 2.0;
        }
    }

    /// The cache row values (exact state chunks + derived filler) for
    /// both layout buffers — the single encoding used by the fixed and
    /// the paged write paths, so the two cache kinds are bit-identical.
    fn row_values(&self, state: u64) -> (Vec<f32>, Vec<f32>) {
        let (i0, i1) = inner_dims(self.spec.layout);
        let mut v0 = vec![0.0f32; i0];
        let mut v1 = vec![0.0f32; i1];
        for j in 0..i0 + i1 {
            let val = if j < STATE_CHUNKS {
                // 100^9 < 2^64: the divisor never overflows u64.
                ((state / 100u64.pow(j as u32)) % 100) as f32
            } else {
                unit(mix(state, 0xF1_11ED ^ j as u64)) * 2.0 - 1.0
            };
            if j < i0 {
                v0[j] = val;
            } else {
                v1[j - i0] = val;
            }
        }
        (v0, v1)
    }

    /// Write the state row into a pair of cache buffers shaped
    /// `[L, B, T, inner]`, at (layer, row, pos), all layers.
    fn write_rows(&self, bufs: &mut [Tensor], row: usize, pos: usize, state: u64) {
        let (v0, v1) = self.row_values(state);
        let (b, t) = (bufs[0].shape[1], bufs[0].shape[2]);
        let (i0, i1) = (v0.len(), v1.len());
        for l in 0..self.spec.n_layers {
            let o0 = ((l * b + row) * t + pos) * i0;
            bufs[0].data[o0..o0 + i0].copy_from_slice(&v0);
            let o1 = ((l * b + row) * t + pos) * i1;
            bufs[1].data[o1..o1 + i1].copy_from_slice(&v1);
        }
    }

    /// Reconstruct the prefix state stored at (slot, pos), layer 0.
    fn read_state(&self, cache: &KvCache, slot: usize, pos: usize) -> u64 {
        let (i0, i1) = inner_dims(self.spec.layout);
        // Layer 0 rows of buffers shaped [L, B, T, inner].
        let t = cache.bufs[0].shape[2];
        let o0 = (slot * t + pos) * i0;
        let o1 = (slot * t + pos) * i1;
        state_of_rows(
            &cache.bufs[0].data[o0..o0 + i0],
            &cache.bufs[1].data[o1..o1 + i1],
        )
    }

    /// One decode step for one slot over the fixed padded pool.
    fn decode_slot_fixed(&self, cache: &mut KvCache, slot: usize, token: i32, p: usize) -> u64 {
        let prev = if p == 0 {
            self.base_state
        } else {
            self.read_state(cache, slot, p - 1)
        };
        let state = step_state(prev, token, p);
        self.write_rows(&mut cache.bufs, slot, p, state);
        state
    }

    /// One decode step for one slot over the paged block pool. Returns
    /// `None` for idle slots (block table does not cover the write
    /// position — the paged equivalent of position masking).
    fn decode_slot_paged(
        &self,
        cache: &mut PagedKvCache,
        slot: usize,
        token: i32,
        p: usize,
    ) -> Result<Option<u64>> {
        if !cache.covers(slot, p) {
            return Ok(None);
        }
        let prev = if p == 0 {
            self.base_state
        } else {
            state_of_rows(cache.row(0, slot, 0, p - 1)?, cache.row(1, slot, 0, p - 1)?)
        };
        let state = step_state(prev, token, p);
        let (v0, v1) = self.row_values(state);
        for l in 0..self.spec.n_layers {
            cache.row_mut(0, slot, l, p)?.copy_from_slice(&v0);
            cache.row_mut(1, slot, l, p)?.copy_from_slice(&v1);
        }
        Ok(Some(state))
    }
}

impl ExecBackend for SimBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    /// The sim signs the dual-stream contract: every internal method is
    /// `&self` (the `&mut` receivers below exist only for the XLA ABI),
    /// `prefill_chunk` touches only `slot`'s cache rows, and `decode`
    /// skips inactive slots entirely — so a concurrent chunk/decode pair
    /// over disjoint slot sets reads and writes disjoint memory.
    fn supports_overlap(&self) -> bool {
        true
    }

    fn prefill(&mut self, tokens: &[i32], rows: usize) -> Result<PrefillOut> {
        let (bp, t, v) = (self.spec.prefill_batch, self.spec.prefill_seq, self.spec.vocab);
        if rows == 0 || rows > bp {
            bail!("sim prefill rows {rows} out of range (prefill_batch {bp})");
        }
        if tokens.len() != rows * t {
            bail!(
                "sim prefill wants {} tokens for {rows} rows, got {}",
                rows * t,
                tokens.len()
            );
        }
        // Buffers are sized to the admitted rows, not the full prefill
        // batch — admitting one short prompt no longer zero-fills (and
        // scans) a `[Bp, T, V]` logits buffer.
        let (i0, i1) = inner_dims(self.spec.layout);
        let l = self.spec.n_layers;
        let mut caches = vec![
            Tensor::zeros(&[l, rows, t, i0]),
            Tensor::zeros(&[l, rows, t, i1]),
        ];
        let mut logits = Tensor::zeros(&[rows, t, v]);
        for row in 0..rows {
            let mut state = self.base_state;
            for pos in 0..t {
                state = step_state(state, tokens[row * t + pos], pos);
                self.write_rows(&mut caches, row, pos, state);
                let off = (row * t + pos) * v;
                self.logits_row(state, &mut logits.data[off..off + v]);
            }
        }
        Ok(PrefillOut { logits, caches })
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        slot: usize,
        start_pos: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        let v = self.spec.vocab;
        let end = tokens.len();
        if start_pos >= end {
            bail!("sim prefill_chunk: empty chunk ({start_pos}..{end})");
        }
        if end > self.spec.capacity {
            bail!(
                "sim prefill_chunk: {end} tokens exceed capacity {}",
                self.spec.capacity
            );
        }
        if slot >= self.spec.batch {
            bail!("sim prefill_chunk: slot {slot} out of range");
        }
        // Exact resume: the rolling state lives in the cache row at
        // `start_pos - 1`, for either store — chunked prefill is
        // bit-identical to monolithic by construction.
        let mut state = if start_pos == 0 {
            self.base_state
        } else {
            match cache {
                CacheStore::Fixed(kv) => self.read_state(kv, slot, start_pos - 1),
                CacheStore::Paged(p) => state_of_rows(
                    p.row(0, slot, 0, start_pos - 1)?,
                    p.row(1, slot, 0, start_pos - 1)?,
                ),
            }
        };
        for pos in start_pos..end {
            state = step_state(state, tokens[pos], pos);
            match cache {
                CacheStore::Fixed(kv) => self.write_rows(&mut kv.bufs, slot, pos, state),
                CacheStore::Paged(p) => {
                    let (v0, v1) = self.row_values(state);
                    for l in 0..self.spec.n_layers {
                        p.row_mut(0, slot, l, pos)?.copy_from_slice(&v0);
                        p.row_mut(1, slot, l, pos)?.copy_from_slice(&v1);
                    }
                }
            }
        }
        let mut logits = Tensor::zeros(&[v]);
        self.logits_row(state, &mut logits.data);
        Ok(logits)
    }

    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        let (b, v) = (self.spec.batch, self.spec.vocab);
        if tokens.len() != b || pos.len() != b || active.len() != b {
            bail!("sim decode wants {b} tokens+positions+active flags");
        }
        match cache {
            CacheStore::Fixed(kv) => {
                if kv.capacity != self.spec.capacity || kv.batch != b {
                    bail!(
                        "sim decode cache geometry {}x{} != spec {}x{}",
                        kv.batch, kv.capacity, b, self.spec.capacity
                    );
                }
            }
            CacheStore::Paged(p) => {
                let (i0, i1) = inner_dims(self.spec.layout);
                if p.n_slots() != b || p.inner_dim(0) != i0 || p.inner_dim(1) != i1 {
                    bail!(
                        "sim decode paged cache geometry ({} slots, inner \
                         {}x{}) != spec ({b} slots, inner {i0}x{i1})",
                        p.n_slots(), p.inner_dim(0), p.inner_dim(1)
                    );
                }
            }
        }
        let mut logits = Tensor::zeros(&[b, v]);
        for slot in 0..b {
            // Inactive slots (idle or mid-prefill) are skipped entirely:
            // a prefilling slot's cache rows are live resume state for
            // the next chunk, so even a "harmless" pos-0 write would
            // corrupt it. Their logits rows stay zero.
            if !active[slot] {
                continue;
            }
            let p = pos[slot] as usize;
            if p >= self.spec.capacity {
                bail!("sim decode position {p} >= capacity {}", self.spec.capacity);
            }
            // The paged arm additionally skips slots whose block table
            // does not cover the write position — active slots produce
            // identical states either way, so the two cache kinds are
            // completion-identical by construction.
            let state = match cache {
                CacheStore::Fixed(kv) => {
                    Some(self.decode_slot_fixed(kv, slot, tokens[slot], p))
                }
                CacheStore::Paged(pc) => {
                    self.decode_slot_paged(pc, slot, tokens[slot], p)?
                }
            };
            if let Some(state) = state {
                self.logits_row(state, &mut logits.data[slot * v..(slot + 1) * v]);
            }
        }
        Ok(logits)
    }

    /// The sim scores candidate chains with exact cache semantics: each
    /// fed token runs the same per-slot step as [`ExecBackend::decode`]
    /// (read state, mix, write row), so a verify call is bit-identical
    /// to the equivalent serial decode calls by construction.
    fn supports_verify(&self) -> bool {
        true
    }

    fn verify(
        &mut self,
        tokens: &[i32],
        start_pos: &[i32],
        counts: &[usize],
        k: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        let (b, v) = (self.spec.batch, self.spec.vocab);
        if k == 0 {
            bail!("sim verify: k must be >= 1");
        }
        if tokens.len() != b * k || start_pos.len() != b || counts.len() != b {
            bail!(
                "sim verify wants a [{b}, {k}] token matrix plus {b} start \
                 positions and counts"
            );
        }
        let mut logits = Tensor::zeros(&[b, k, v]);
        for slot in 0..b {
            let n = counts[slot];
            if n == 0 {
                continue;
            }
            if n > k {
                bail!("sim verify: slot {slot} count {n} exceeds k {k}");
            }
            let p0 = start_pos[slot] as usize;
            if p0 + n > self.spec.capacity {
                bail!(
                    "sim verify: slot {slot} positions {p0}..{} exceed capacity {}",
                    p0 + n,
                    self.spec.capacity
                );
            }
            for j in 0..n {
                let p = p0 + j;
                let tok = tokens[slot * k + j];
                let state = match cache {
                    CacheStore::Fixed(kv) => {
                        Some(self.decode_slot_fixed(kv, slot, tok, p))
                    }
                    CacheStore::Paged(pc) => self.decode_slot_paged(pc, slot, tok, p)?,
                };
                match state {
                    Some(state) => {
                        let off = (slot * k + j) * v;
                        self.logits_row(state, &mut logits.data[off..off + v]);
                    }
                    // Unlike decode's position masking, an uncovered
                    // verify position is an engine bug: the caller grows
                    // the slot over the whole candidate chain first.
                    None => bail!(
                        "sim verify: slot {slot} block table does not cover \
                         position {p}"
                    ),
                }
            }
        }
        Ok(logits)
    }
}

fn inner_dims(layout: CacheLayout) -> (usize, usize) {
    layout.inner_dims()
}

/// Reconstruct the prefix state from one cache row's two inner slices.
/// Digits are read with round-to-nearest so any lossy row codec whose
/// per-value error stays under 0.5 (e.g. per-row int8) is transparent;
/// `rem_euclid` keeps a badly drifted value (e.g. fp8) a valid digit,
/// so reads stay deterministic rather than UB. The sum is accumulated
/// in u128 (`100^10 > 2^64`) and truncated.
fn state_of_rows(r0: &[f32], r1: &[f32]) -> u64 {
    let mut state = 0u128;
    for j in 0..STATE_CHUNKS {
        let val = if j < r0.len() { r0[j] } else { r1[j - r0.len()] };
        let digit = (val.round() as i64).rem_euclid(100) as u128;
        state += digit * 100u128.pow(j as u32);
    }
    state as u64
}

/// SplitMix64-style avalanche of `a` perturbed by `b`.
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn step_state(state: u64, token: i32, pos: usize) -> u64 {
    mix(mix(state, token as i64 as u64 ^ 0x70C0), pos as u64 ^ 0x9E37)
}

/// Map a hash to [0, 1).
fn unit(h: u64) -> f32 {
    ((h >> 40) as f32) / (1u64 << 24) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompt() -> Vec<i32> {
        "the latent cache".bytes().map(|b| b as i32).collect()
    }

    fn padded(tokens: &[i32], bp: usize, t: usize, row: usize) -> Vec<i32> {
        let mut m = vec![0i32; bp * t];
        m[row * t..row * t + tokens.len()].copy_from_slice(tokens);
        m
    }

    #[test]
    fn shapes_match_contract_both_layouts() {
        for mut be in [SimBackend::gqa(4), SimBackend::mla(4, 4)] {
            let s = be.spec().clone();
            let out = be
                .prefill(&padded(&prompt(), s.prefill_batch, s.prefill_seq, 0), s.prefill_batch)
                .unwrap();
            assert_eq!(out.logits.shape, vec![s.prefill_batch, s.prefill_seq, s.vocab]);
            assert_eq!(out.caches.len(), 2);
            assert_eq!(out.caches[0].shape[..3], [s.n_layers, s.prefill_batch, s.prefill_seq]);
            let mut cache = CacheStore::Fixed(s.new_cache());
            let logits = be
                .decode(
                    &vec![7; s.batch],
                    &vec![3; s.batch],
                    &vec![true; s.batch],
                    &mut cache,
                )
                .unwrap();
            assert_eq!(logits.shape, vec![s.batch, s.vocab]);
        }
    }

    #[test]
    fn prefill_sizes_buffers_to_the_admitted_rows() {
        // Regression for the full-batch zero-fill: one admitted prompt
        // must not allocate (or compute) a `[Bp, T, V]` logits buffer.
        let mut be = SimBackend::gqa(8);
        let s = be.spec().clone();
        let toks = prompt();
        let one = be.prefill(&padded(&toks, 1, s.prefill_seq, 0), 1).unwrap();
        assert_eq!(one.logits.shape, vec![1, s.prefill_seq, s.vocab]);
        assert_eq!(one.caches[0].shape[1], 1, "cache rows sized to request");
        // Row content is identical to the same prompt in a full batch.
        let full = be
            .prefill(&padded(&toks, s.prefill_batch, s.prefill_seq, 0), s.prefill_batch)
            .unwrap();
        let n = s.prefill_seq * s.vocab;
        assert_eq!(one.logits.data[..n], full.logits.data[..n]);
        // Bad rows counts are rejected.
        assert!(be.prefill(&padded(&toks, 1, s.prefill_seq, 0), 2).is_err());
        assert!(be.prefill(&padded(&toks, 1, s.prefill_seq, 0), 0).is_err());
    }

    #[test]
    fn decode_reproduces_prefill_logits() {
        // The invariant the runtime integration suite proves through HLO:
        // re-decoding position p over the prefill cache reproduces the
        // prefill logits at p.
        let mut be = SimBackend::gqa(4);
        let s = be.spec().clone();
        let toks = prompt();
        let out = be
            .prefill(&padded(&toks, s.prefill_batch, s.prefill_seq, 2), s.prefill_batch)
            .unwrap();
        let mut fixed = s.new_cache();
        fixed.splice_from(&out.caches, 2, 1).unwrap();
        let mut cache = CacheStore::Fixed(fixed);

        let p = toks.len() - 1;
        let mut dt = vec![0i32; s.batch];
        let mut dp = vec![0i32; s.batch];
        let mut act = vec![false; s.batch];
        dt[1] = toks[p];
        dp[1] = p as i32;
        act[1] = true;
        let logits = be.decode(&dt, &dp, &act, &mut cache).unwrap();
        let want = &out.logits.data[(2 * s.prefill_seq + p) * s.vocab..][..s.vocab];
        let got = &logits.data[s.vocab..2 * s.vocab];
        assert_eq!(want, got, "decode diverged from prefill at pos {p}");
    }

    #[test]
    fn paged_decode_matches_fixed_decode_and_prefill() {
        // The paged block pool must reproduce the fixed pool bit-exactly
        // for active slots, and leave idle slots inert.
        for mut be in [SimBackend::gqa(4), SimBackend::mla(4, 4)] {
            let s = be.spec().clone();
            let toks = prompt();
            let out = be
                .prefill(&padded(&toks, s.prefill_batch, s.prefill_seq, 2), s.prefill_batch)
                .unwrap();

            let mut fixed = s.new_cache();
            fixed.splice_from(&out.caches, 2, 1).unwrap();
            let mut fixed = CacheStore::Fixed(fixed);

            let mut paged = crate::kvcache::PagedKvCache::new(
                s.layout, s.n_layers, s.batch, 8, 64,
            )
            .unwrap();
            paged.admit_slot(1, toks.len() + 4, toks.len()).unwrap();
            paged
                .splice_from(&out.caches, 2, 1, toks.len())
                .unwrap();
            let mut paged = CacheStore::Paged(paged);

            let p = toks.len() - 1;
            let mut dt = vec![0i32; s.batch];
            let mut dp = vec![0i32; s.batch];
            let mut act = vec![false; s.batch];
            dt[1] = toks[p];
            dp[1] = p as i32;
            act[1] = true;
            let lf = be.decode(&dt, &dp, &act, &mut fixed).unwrap();
            let lp = be.decode(&dt, &dp, &act, &mut paged).unwrap();
            assert_eq!(
                lf.data[s.vocab..2 * s.vocab],
                lp.data[s.vocab..2 * s.vocab],
                "paged decode diverged from fixed at pos {p}"
            );
            let want = &out.logits.data[(2 * s.prefill_seq + p) * s.vocab..][..s.vocab];
            assert_eq!(want, &lp.data[s.vocab..2 * s.vocab]);
            // Idle slots (no block table) produced no logits energy.
            assert!(lp.data[..s.vocab].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn rows_are_independent_and_deterministic() {
        let mut a = SimBackend::gqa(2);
        let mut b = SimBackend::gqa(2);
        let s = a.spec().clone();
        let solo = a
            .prefill(&padded(&prompt(), s.prefill_batch, s.prefill_seq, 0), s.prefill_batch)
            .unwrap();
        // Same prompt in row 0, different garbage in row 1.
        let mut mixed_toks = padded(&prompt(), s.prefill_batch, s.prefill_seq, 0);
        for (i, tok) in mixed_toks[s.prefill_seq..].iter_mut().enumerate() {
            *tok = (i % 250) as i32 + 1;
        }
        let mixed = b.prefill(&mixed_toks, s.prefill_batch).unwrap();
        let n = s.prefill_seq * s.vocab;
        assert_eq!(solo.logits.data[..n], mixed.logits.data[..n]);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bit_exactly() {
        // The chunk entry point must resume from the cache and reproduce
        // the monolithic prefill bit-for-bit: same final logits, same
        // cache rows over the prompt — for both layouts and both stores,
        // across uneven chunk boundaries.
        for mut be in [SimBackend::gqa(4), SimBackend::mla(4, 4)] {
            let s = be.spec().clone();
            let toks = prompt();
            let plen = toks.len();
            // Monolithic reference: one-row prefill spliced into slot 1.
            let out = be.prefill(&padded(&toks, 1, s.prefill_seq, 0), 1).unwrap();
            let mut mono = s.new_cache();
            mono.splice_from(&out.caches, 0, 1).unwrap();

            let mut fixed = CacheStore::Fixed(s.new_cache());
            let mut paged =
                crate::kvcache::PagedKvCache::new(s.layout, s.n_layers, s.batch, 8, 64)
                    .unwrap();
            paged.admit_slot(1, plen + 1, plen).unwrap();
            let mut paged = CacheStore::Paged(paged);

            let mut start = 0usize;
            let mut last: Option<(Tensor, Tensor)> = None;
            for end in [1usize, 3, 9, plen] {
                let lf = be.prefill_chunk(&toks[..end], 1, start, &mut fixed).unwrap();
                let lp = be.prefill_chunk(&toks[..end], 1, start, &mut paged).unwrap();
                assert_eq!(lf.data, lp.data, "stores diverged at chunk end {end}");
                last = Some((lf, lp));
                start = end;
            }
            // Final chunk logits == monolithic logits at the last prompt
            // position.
            let want = &out.logits.data[(plen - 1) * s.vocab..][..s.vocab];
            let (lf, lp) = last.unwrap();
            assert_eq!(want, &lf.data[..]);
            assert_eq!(want, &lp.data[..]);
            // Fixed-store chunked cache rows == monolithic spliced rows
            // over every prompt position, every layer, both buffers.
            if let CacheStore::Fixed(kv) = &fixed {
                for (buf, (mine, theirs)) in
                    kv.bufs.iter().zip(mono.bufs.iter()).enumerate()
                {
                    // Inner width per position (GQA bufs are [L,B,T,g,d],
                    // MLA [L,B,T,r]): the product of the trailing dims.
                    let inner: usize = mine.shape[3..].iter().product();
                    let (b, t) = (mine.shape[1], mine.shape[2]);
                    for l in 0..s.n_layers {
                        for pos in 0..plen {
                            let off = ((l * b + 1) * t + pos) * inner;
                            assert_eq!(
                                mine.data[off..off + inner],
                                theirs.data[off..off + inner],
                                "buf {buf} layer {l} pos {pos} diverged"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn verify_is_bit_identical_to_serial_decodes_on_both_stores() {
        // A k-token verify call must reproduce k serial decode calls
        // exactly: same logits rows, same cache rows — the contract the
        // speculative engine's temp-0 bit-identity rests on.
        for mut be in [SimBackend::gqa(4), SimBackend::mla(4, 4)] {
            let s = be.spec().clone();
            let toks = prompt();
            let plen = toks.len();
            let k = 3;
            let chain = [17i32, 99, 204];
            let build = |be: &mut SimBackend, paged: bool| -> CacheStore {
                let out = be.prefill(&padded(&toks, 1, s.prefill_seq, 0), 1).unwrap();
                if paged {
                    let mut p = crate::kvcache::PagedKvCache::new(
                        s.layout, s.n_layers, s.batch, 8, 64,
                    )
                    .unwrap();
                    p.admit_slot(1, plen + k + 1, plen).unwrap();
                    p.grow(1, plen + k).unwrap();
                    p.splice_from(&out.caches, 0, 1, plen).unwrap();
                    CacheStore::Paged(p)
                } else {
                    let mut kv = s.new_cache();
                    kv.splice_from(&out.caches, 0, 1).unwrap();
                    CacheStore::Fixed(kv)
                }
            };
            for paged in [false, true] {
                let mut serial = build(&mut be, paged);
                let mut serial_rows = Vec::new();
                for (j, &tok) in chain.iter().enumerate() {
                    let mut dt = vec![0i32; s.batch];
                    let mut dp = vec![0i32; s.batch];
                    let mut act = vec![false; s.batch];
                    dt[1] = tok;
                    dp[1] = (plen - 1 + j) as i32;
                    act[1] = true;
                    let l = be.decode(&dt, &dp, &act, &mut serial).unwrap();
                    serial_rows.push(l.data[s.vocab..2 * s.vocab].to_vec());
                }
                let mut batched = build(&mut be, paged);
                let mut vt = vec![0i32; s.batch * k];
                let mut vp = vec![0i32; s.batch];
                let mut counts = vec![0usize; s.batch];
                vt[k..2 * k].copy_from_slice(&chain);
                vp[1] = (plen - 1) as i32;
                counts[1] = k;
                let vl = be.verify(&vt, &vp, &counts, k, &mut batched).unwrap();
                assert_eq!(vl.shape, vec![s.batch, k, s.vocab]);
                for (j, want) in serial_rows.iter().enumerate() {
                    let off = (k + j) * s.vocab;
                    assert_eq!(
                        &vl.data[off..off + s.vocab],
                        &want[..],
                        "row {j} diverged (paged={paged})"
                    );
                }
                // Idle slots produced no logits energy.
                assert!(vl.data[..k * s.vocab].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn state_roundtrips_through_cache_chunks() {
        let be = SimBackend::mla(2, 4);
        let mut cache = be.spec().new_cache();
        let state = 0xDEAD_BEEF_CAFE_1234u64;
        let mut bufs = std::mem::take(&mut cache.bufs);
        be.write_rows(&mut bufs, 1, 5, state);
        cache.bufs = bufs;
        assert_eq!(be.read_state(&cache, 1, 5), state);
    }
}
