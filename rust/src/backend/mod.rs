//! Execution backends: the layer below the serving engine.
//!
//! An [`ExecBackend`] is anything that can run the three model entry
//! points the StepPlan pipeline needs:
//!
//!   * **prefill** — a rows-sized `[rows, T]` token matrix in,
//!     per-position logits `[rows, T, V]` plus per-row caches
//!     `[L, rows, T, ...]` out (the monolithic admission path);
//!   * **prefill_chunk** — resumable single-sequence prefill: one prompt
//!     prefix in, the chunk's cache rows written in place into the
//!     sequence's slot, last-position logits `[V]` out (the chunked,
//!     decode-overlapped admission path);
//!   * **decode** — one token + position + active flag per slot in,
//!     next-token logits `[B, V]` out, with the slot caches advanced in
//!     place.
//!
//! Two implementations ship:
//!
//!   * [`XlaBackend`] wraps the AOT-compiled HLO artifacts through the
//!     PJRT runtime (`make artifacts` + real `xla` bindings required) —
//!     the measured-performance path;
//!   * [`SimBackend`] is a deterministic pure-Rust model of the same
//!     contract (both `CacheLayout::Gqa` and `CacheLayout::Mla`), so the
//!     engine, scheduler, server, benches, and integration tests run
//!     hermetically on a bare checkout.
//!
//! The engine (`coordinator::engine`) only ever sees `dyn ExecBackend`;
//! everything XLA-specific lives in [`xla`].

pub mod sim;
pub mod xla;

use crate::config::CacheKind;
use crate::kvcache::{CacheLayout, KvCache, PagedKvCache, QuantKind};
use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub use sim::{SimBackend, SimConfig};
pub use xla::{ModelBundle, XlaBackend};

/// Which architecture a backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Gqa,
    Mla { rank: usize },
}

/// Static geometry of a backend — everything the engine and the
/// sequence manager need to size caches, clamp prompts, and read logits.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    pub arch: Arch,
    /// Human-readable identity (config/artifact name or "sim").
    pub name: String,
    pub layout: CacheLayout,
    pub n_layers: usize,
    pub vocab: usize,
    /// Decode batch width (number of slots).
    pub batch: usize,
    /// Max rows per prefill call.
    pub prefill_batch: usize,
    /// Sequence length of the prefill entry point.
    pub prefill_seq: usize,
    /// Cache capacity T of the decode entry point.
    pub capacity: usize,
}

impl BackendSpec {
    /// Longest admissible prompt: one slot position must remain for the
    /// first generated token, and the prompt must fit both entry points.
    pub fn max_prompt(&self) -> usize {
        self.capacity.min(self.prefill_seq).saturating_sub(1)
    }

    /// A fresh, zeroed slot cache pool matching this spec.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.layout, self.n_layers, self.batch, self.capacity)
    }

    /// Blocks a `block_size`-token paged pool needs per full-capacity
    /// sequence.
    fn blocks_per_seq(&self, block_size: usize) -> usize {
        (self.capacity + block_size - 1) / block_size.max(1)
    }

    /// A fresh cache store of the requested kind. A paged store defaults
    /// to at most the fixed pool's worst-case byte budget —
    /// `batch * capacity` tokens rounded *down* to whole blocks (never
    /// more memory than fixed, even when `block_size` does not divide
    /// `capacity`) — but never fewer blocks than one full-capacity
    /// sequence, so admission can always make progress on a drained
    /// engine. `n_blocks` overrides the default; it must still fit one
    /// full sequence. `prefix_cache` turns on the cross-sequence prefix
    /// index (paged only: the fixed pool has no blocks to share).
    ///
    /// `quant` selects the paged pool's block codec. `n_blocks` (and the
    /// default) are denominated in fp32 worst-case blocks — a *byte
    /// budget* — so a lossy codec converts the same budget into more
    /// blocks (`budget_bytes / encoded_block_bytes`): the admission win
    /// the codec exists for. The fixed pool stores raw f32 rows only.
    pub fn new_cache_store(
        &self,
        kind: CacheKind,
        prefix_cache: bool,
        quant: QuantKind,
    ) -> Result<CacheStore> {
        match kind {
            CacheKind::Fixed => {
                if prefix_cache {
                    bail!(
                        "prefix cache requires the paged cache store \
                         (--cache paged)"
                    );
                }
                if !quant.is_off() {
                    bail!(
                        "kv quantization requires the paged cache store \
                         (--cache paged)"
                    );
                }
                Ok(CacheStore::Fixed(self.new_cache()))
            }
            CacheKind::Paged { block_size, n_blocks } => {
                if block_size == 0 {
                    bail!("paged cache block size must be >= 1");
                }
                let per_seq = self.blocks_per_seq(block_size);
                let budget_blocks = n_blocks
                    .unwrap_or(per_seq.max(self.batch * self.capacity / block_size));
                // The budget is bytes, counted in fp32 worst-case blocks;
                // an encoded block is smaller, so the same bytes buy more
                // blocks. Per-block bytes share the `block_size` factor,
                // so the ratio reduces to bytes-per-token.
                let (i0, i1) = self.layout.inner_dims();
                let fp32_bpt = self.layout.per_token_per_layer() * self.n_layers * 4;
                let enc_bpt =
                    (quant.bytes_per_row(i0) + quant.bytes_per_row(i1)) * self.n_layers;
                let n = budget_blocks * fp32_bpt / enc_bpt.max(1);
                if n < per_seq {
                    bail!(
                        "paged pool of {n} blocks cannot hold one \
                         full-capacity sequence ({per_seq} blocks)"
                    );
                }
                let mut p = PagedKvCache::new_quant(
                    self.layout,
                    self.n_layers,
                    self.batch,
                    block_size,
                    n,
                    quant,
                )?;
                if prefix_cache {
                    p.enable_prefix_cache();
                }
                Ok(CacheStore::Paged(p))
            }
        }
    }
}

/// The engine's cache, behind one seam: the fixed worst-case slot pool
/// (what the XLA decode artifacts operate on) or the paged block pool.
/// Fixed-pool operations that have no paged counterpart are no-ops on
/// the paged arm and vice versa, so the engine stays kind-agnostic.
pub enum CacheStore {
    Fixed(KvCache),
    Paged(PagedKvCache),
}

impl CacheStore {
    pub fn kind_name(&self) -> &'static str {
        match self {
            CacheStore::Fixed(_) => "fixed",
            CacheStore::Paged(_) => "paged",
        }
    }

    pub fn as_fixed_mut(&mut self) -> Option<&mut KvCache> {
        match self {
            CacheStore::Fixed(kv) => Some(kv),
            CacheStore::Paged(_) => None,
        }
    }

    pub fn as_paged(&self) -> Option<&PagedKvCache> {
        match self {
            CacheStore::Fixed(_) => None,
            CacheStore::Paged(p) => Some(p),
        }
    }

    /// Splice prefill output row `src` into `slot`. The paged pool
    /// copies exactly `len` positions (nothing else is materialised),
    /// skipping any shared-prefix positions whose mapped blocks already
    /// hold those rows; the fixed pool keeps its historical
    /// copy-to-capacity behaviour (the padded tail is position-masked
    /// anyway).
    pub fn splice_from(
        &mut self,
        prefill_bufs: &[Tensor],
        src: usize,
        slot: usize,
        len: usize,
    ) -> Result<()> {
        match self {
            CacheStore::Fixed(kv) => kv.splice_from(prefill_bufs, src, slot),
            CacheStore::Paged(p) => p.splice_from(prefill_bufs, src, slot, len),
        }
    }

    /// Bind `slot` to a new sequence: reserve its bounded token demand
    /// and materialise the prompt. With the prefix cache on, the paged
    /// pool first maps the longest indexed prefix of `prompt` into the
    /// slot's table and reserves only the unshared remainder; the return
    /// value is the number of prompt positions already covered by shared
    /// blocks (the caller starts its prefill watermark there). No-op
    /// returning 0 for the fixed pool (the slot row is the reservation).
    pub fn admit_slot(
        &mut self,
        slot: usize,
        reserve_tokens: usize,
        initial_len: usize,
        prompt: &[i32],
    ) -> Result<usize> {
        match self {
            CacheStore::Fixed(_) => Ok(0),
            CacheStore::Paged(p) => {
                p.admit_slot_shared(slot, reserve_tokens, initial_len, prompt)
            }
        }
    }

    /// Index `slot`'s fully-filled prompt blocks for future sharing (call
    /// once the whole prompt is in cache). No-op for the fixed pool or
    /// when the prefix cache is off; returns newly cached blocks.
    pub fn register_prefix(&mut self, slot: usize, prompt: &[i32]) -> Result<usize> {
        match self {
            CacheStore::Fixed(_) => Ok(0),
            CacheStore::Paged(p) => p.register_prefix(slot, prompt),
        }
    }

    /// Freshen the prefix-cache LRU stamp of `prompt`'s cached chain so
    /// same-wave evictions prefer other victims. No-op for the fixed
    /// pool or with sharing off.
    pub fn touch_prefix(&mut self, prompt: &[i32]) {
        if let CacheStore::Paged(p) = self {
            p.touch_prefix(prompt);
        }
    }

    /// Ensure `slot` covers `len` positions before a decode write.
    pub fn grow(&mut self, slot: usize, len: usize) -> Result<()> {
        match self {
            CacheStore::Fixed(_) => Ok(()),
            CacheStore::Paged(p) => p.grow(slot, len),
        }
    }

    /// Retract `slot`'s cache coverage to at most `len` token positions
    /// — the speculative-decode rollback seam. Paged: tail blocks past
    /// the new end are released back to the allocator, refcount-correct
    /// under prefix sharing (see [`PagedKvCache::truncate`]). Fixed: a
    /// no-op — the slot row stays reserved and correctness comes from
    /// position masking; the retracted rows are simply overwritten by
    /// the next decode step at the same positions.
    pub fn truncate(&mut self, slot: usize, len: usize) -> Result<()> {
        match self {
            CacheStore::Fixed(_) => Ok(()),
            CacheStore::Paged(p) => p.truncate(slot, len),
        }
    }

    /// Return `slot`'s memory to the pool. Paged: blocks go back to the
    /// free list. Fixed: a no-op — the slot row stays reserved by
    /// construction and correctness comes from position masking, so
    /// zeroing it (`KvCache::clear_slot`) would be a pure-hygiene
    /// multi-MB memset on the completion hot path.
    pub fn release_slot(&mut self, slot: usize) -> Result<()> {
        match self {
            CacheStore::Fixed(_) => Ok(()),
            CacheStore::Paged(p) => p.release_slot(slot).map(|_| ()),
        }
    }

    pub fn bytes_total(&self) -> usize {
        match self {
            CacheStore::Fixed(kv) => kv.bytes_total(),
            CacheStore::Paged(p) => p.bytes_total(),
        }
    }

    /// Bytes actually committed: the whole pool for the fixed cache
    /// (every slot row is reserved up front), allocated blocks only for
    /// the paged cache.
    pub fn bytes_in_use(&self) -> usize {
        match self {
            CacheStore::Fixed(kv) => kv.bytes_total(),
            CacheStore::Paged(p) => p.bytes_in_use(),
        }
    }

    pub fn check_invariants(&self) -> Result<()> {
        match self {
            CacheStore::Fixed(_) => Ok(()),
            CacheStore::Paged(p) => p.check_invariants(),
        }
    }
}

/// Output of one batched prefill call.
pub struct PrefillOut {
    /// Per-position logits `[rows, T, V]` (`SimBackend` sizes the rows
    /// dim to the request; `XlaBackend` always returns the artifact's
    /// full `[Bp, T, V]`).
    pub logits: Tensor,
    /// Cache tensors `[L, rows, T, ...]` in the layout's buffer order
    /// (GQA: k, v; MLA: latent, rope-key), same rows convention.
    pub caches: Vec<Tensor>,
}

/// A model execution backend (prefill + decode over an opaque cache).
///
/// `Send` because engines run on worker threads in `--workers` mode.
/// The vendored `xla` stub's handle types are field-less (auto-`Send`);
/// real PJRT bindings are Rc-backed and would need a `Send` wrapper (or
/// a per-thread client) before `XlaBackend` engines could leave the
/// spawning thread — the stub keeps the bound honest at compile time
/// without claiming the real runtime is thread-safe.
pub trait ExecBackend: Send {
    fn spec(&self) -> &BackendSpec;

    /// Opt-in to dual-stream execution: may the engine run ONE
    /// `prefill_chunk` call and ONE `decode` call on this backend
    /// *concurrently* (two threads, same backend, same cache store)?
    ///
    /// Returning `true` promises, for the duration of such a pair:
    ///   * both entry points are interiorly immutable — they never
    ///     mutate backend state, even though the trait takes `&mut self`
    ///     (the receiver is `&mut` only for XLA's buffer-donation ABI);
    ///   * each call reads and writes ONLY the cache rows of the slots
    ///     named in its arguments (`slot` for `prefill_chunk`; the
    ///     `active` slots for `decode`), so calls over disjoint slot
    ///     sets touch disjoint memory.
    ///
    /// The engine pairs this with the cache-side invariant (no
    /// allocator/table mutation during the streams — see
    /// `Engine::overlapped_chunk_decode_step`) to build the aliased
    /// `&mut` seam. Default `false`: overlap is gated off unless a
    /// backend explicitly signs the contract. `XlaBackend` stays `false`
    /// — its decode artifact writes pos-0 rows for *inactive* slots
    /// (fixed AOT ABI), which would race the prefill stream.
    fn supports_overlap(&self) -> bool {
        false
    }

    /// Run batched prefill over `rows` prompts packed row-major into a
    /// `rows * prefill_seq` token matrix (`rows <= prefill_batch`;
    /// unused positions zero). `SimBackend` sizes its compute and output
    /// buffers to `rows`; `XlaBackend` pads back up to the artifact's
    /// fixed `[Bp, T]` shape internally, so the AOT ABI is untouched.
    fn prefill(&mut self, tokens: &[i32], rows: usize) -> Result<PrefillOut>;

    /// Resumable chunked prefill for ONE sequence. `tokens` is the
    /// prompt prefix up to the end of this chunk; positions
    /// `start_pos..tokens.len()` are new. Writes those cache rows
    /// straight into `slot`'s rows of `cache` and returns the logits row
    /// `[vocab]` at the chunk's last position. `SimBackend` resumes
    /// exactly from the cache state at `start_pos - 1` (both layouts,
    /// both stores); `XlaBackend` recomputes the prefix through its
    /// fixed-shape prefill artifact and re-splices positions
    /// `0..tokens.len()` — the AOT contract is untouched, chunking there
    /// trades recompute for decode overlap.
    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        slot: usize,
        start_pos: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor>;

    /// Advance the decoding slots one step: `tokens[s]` / `pos[s]` are
    /// the last sampled token and its write position for slot `s`, and
    /// `active[s]` marks the slots decoding this step (idle and
    /// mid-prefill slots are false, with `tokens`/`pos` zeroed).
    /// Backends must leave inactive slots untouched where the store
    /// allows it — a mid-prefill slot holds live cache rows that a later
    /// chunk will resume from. (The XLA decode artifacts write pos-0
    /// rows for inactive slots — fixed ABI — which is safe there because
    /// the chunked XLA path re-splices the whole prefix.) Updates
    /// `cache` in place and returns logits `[batch * vocab]`. Backends
    /// may reject cache kinds they cannot drive (the XLA artifacts
    /// require the fixed padded pool).
    fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        active: &[bool],
        cache: &mut CacheStore,
    ) -> Result<Tensor>;

    /// Opt-in to batched multi-token verification: can this backend
    /// score k candidate tokens per slot in one [`ExecBackend::verify`]
    /// call? Default `false` — the engine then stays on the serial
    /// one-token decode path, the same opt-in pattern as
    /// [`ExecBackend::supports_overlap`]. `XlaBackend` stays `false`:
    /// its decode artifact is AOT-compiled for exactly one position per
    /// slot per call.
    fn supports_verify(&self) -> bool {
        false
    }

    /// Score up to `k` candidate tokens per slot in one call — the
    /// target-model half of speculative decoding. `tokens` is a
    /// row-major `[batch, k]` matrix; for slot `s`, `counts[s]` (0 for
    /// slots sitting this step out, `<= k` otherwise) tokens starting at
    /// `tokens[s * k]` are fed at consecutive positions
    /// `start_pos[s] ..`. Semantics per position are EXACTLY those of
    /// `k` serial [`ExecBackend::decode`] calls: the cache row for each
    /// fed token is written in place, and output row `j` of the returned
    /// `[batch, k, vocab]` tensor holds the logits predicting the token
    /// after position `start_pos[s] + j` (rows `counts[s]..` stay zero).
    /// The engine accepts a prefix of the candidates and calls
    /// [`CacheStore::truncate`] to retract the cache writes of rejected
    /// ones, so a verify overshoot is never observable.
    fn verify(
        &mut self,
        tokens: &[i32],
        start_pos: &[i32],
        counts: &[usize],
        k: usize,
        cache: &mut CacheStore,
    ) -> Result<Tensor> {
        let _ = (tokens, start_pos, counts, k, cache);
        bail!(
            "backend `{}` does not support batched verify (supports_verify \
             is false); the engine must stay on the serial decode path",
            self.spec().name
        )
    }
}
