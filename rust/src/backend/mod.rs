//! Execution backends: the layer below the serving engine.
//!
//! An [`ExecBackend`] is anything that can run the two model entry points
//! the continuous batcher needs:
//!
//!   * **prefill** — a fixed-shape `[Bp, T]` token matrix in, per-position
//!     logits `[Bp, T, V]` plus per-row caches `[L, Bp, T, ...]` out;
//!   * **decode** — one token + position per slot in, next-token logits
//!     `[B, V]` out, with the slot caches advanced in place.
//!
//! Two implementations ship:
//!
//!   * [`XlaBackend`] wraps the AOT-compiled HLO artifacts through the
//!     PJRT runtime (`make artifacts` + real `xla` bindings required) —
//!     the measured-performance path;
//!   * [`SimBackend`] is a deterministic pure-Rust model of the same
//!     contract (both `CacheLayout::Gqa` and `CacheLayout::Mla`), so the
//!     engine, scheduler, server, benches, and integration tests run
//!     hermetically on a bare checkout.
//!
//! The engine (`coordinator::engine`) only ever sees `dyn ExecBackend`;
//! everything XLA-specific lives in [`xla`].

pub mod sim;
pub mod xla;

use crate::kvcache::{CacheLayout, KvCache};
use crate::tensor::Tensor;
use anyhow::Result;

pub use sim::{SimBackend, SimConfig};
pub use xla::{ModelBundle, XlaBackend};

/// Which architecture a backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Gqa,
    Mla { rank: usize },
}

/// Static geometry of a backend — everything the engine and the
/// sequence manager need to size caches, clamp prompts, and read logits.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    pub arch: Arch,
    /// Human-readable identity (config/artifact name or "sim").
    pub name: String,
    pub layout: CacheLayout,
    pub n_layers: usize,
    pub vocab: usize,
    /// Decode batch width (number of slots).
    pub batch: usize,
    /// Max rows per prefill call.
    pub prefill_batch: usize,
    /// Sequence length of the prefill entry point.
    pub prefill_seq: usize,
    /// Cache capacity T of the decode entry point.
    pub capacity: usize,
}

impl BackendSpec {
    /// Longest admissible prompt: one slot position must remain for the
    /// first generated token, and the prompt must fit both entry points.
    pub fn max_prompt(&self) -> usize {
        self.capacity.min(self.prefill_seq).saturating_sub(1)
    }

    /// A fresh, zeroed slot cache pool matching this spec.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(self.layout, self.n_layers, self.batch, self.capacity)
    }
}

/// Output of one prefill call.
pub struct PrefillOut {
    /// Per-position logits `[Bp, T, V]`.
    pub logits: Tensor,
    /// Cache tensors `[L, Bp, T, ...]` in the layout's buffer order
    /// (GQA: k, v; MLA: latent, rope-key).
    pub caches: Vec<Tensor>,
}

/// A model execution backend (prefill + decode over an opaque cache).
pub trait ExecBackend {
    fn spec(&self) -> &BackendSpec;

    /// Run prefill over a padded `[prefill_batch * prefill_seq]` token
    /// matrix (row-major; unused rows/positions zero).
    fn prefill(&mut self, tokens: &[i32]) -> Result<PrefillOut>;

    /// Advance every slot one step: `tokens[s]` / `pos[s]` are the last
    /// sampled token and its write position for slot `s` (0/0 for idle
    /// slots — backends must be position-masked so idle slots are inert).
    /// Updates `cache` in place and returns logits `[batch * vocab]`.
    fn decode(&mut self, tokens: &[i32], pos: &[i32], cache: &mut KvCache) -> Result<Tensor>;
}
