//! API stub for the `xla` PJRT bindings (xla_extension 0.5.1).
//!
//! The offline build image cannot link the native XLA runtime, so this
//! crate provides the exact type/function surface `transmla::runtime`
//! compiles against, with every fallible operation returning a clear
//! "XLA runtime unavailable" error at *runtime*. The serving stack does
//! not depend on it working: the hermetic `SimBackend` drives the engine,
//! server, benches, and integration tests with no artifacts at all.
//!
//! To execute real AOT artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at a checkout of the real bindings (same API); no
//! source change in `transmla` is required.

use std::fmt;

/// Error type mirroring the real bindings' error enum surface-wise.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(op: &str) -> Error {
    Error {
        msg: format!(
            "XLA runtime unavailable ({op}): built against the bundled API \
             stub — point the `xla` path dependency at the real \
             xla_extension bindings to execute artifacts"
        ),
    }
}

/// Element dtype of a literal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal (tensor value crossing the PJRT boundary).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn ty(&self) -> Result<ElementType> {
        Err(unavailable("Literal::ty"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle (Rc-backed in the real bindings; not `Send`).
#[derive(Debug, Clone)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Wrapped XLA computation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("XLA runtime unavailable"), "{e}");
    }
}
