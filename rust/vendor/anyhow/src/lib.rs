//! Offline stand-in for the `anyhow` crate, API-compatible with the
//! subset this repository uses: `Result`, `Error`, `Context` (on both
//! `Result` and `Option`), and the `anyhow!` / `bail!` macros.
//!
//! The build environment has no registry access, so the error type is a
//! plain message string with context chaining (`"ctx: cause"`), which is
//! exactly how the call sites consume it (`{e}` / `{e:#}` formatting and
//! `to_string()`); nothing here downcasts.

use std::fmt;

/// A string-backed error with context chaining.
///
/// Deliberately does NOT implement `std::error::Error` so that the
/// blanket `From<E: std::error::Error>` below does not conflict with the
/// reflexive `From<Error> for Error` (the same trick real `anyhow` uses).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer: `"{ctx}: {self}"`.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` with any displayable
/// error, or `Option`).
pub trait Context<T> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, ctx: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke at {}", 7);
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at 7");
    }

    #[test]
    fn context_on_option_and_result() {
        let o: Option<u32> = None;
        let e = o.context("missing thing").unwrap_err();
        assert_eq!(e.to_string(), "missing thing");

        let r: Result<u32> = fails().context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: broke at 7");

        let r: Result<u32> = fails().with_context(|| format!("layer {}", 2));
        assert_eq!(r.unwrap_err().to_string(), "layer 2: broke at 7");
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file/xyz")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}
