"""L2: JAX transformer models — GQA baseline, merged/rotated analysis form,
and the TransMLA (absorbed + trainable) forms.

Everything here is build-time only: ``aot.py`` lowers these entry points to
HLO text once; the Rust coordinator executes them via PJRT with no Python
on the request path.

Conventions
-----------
* Row-vector convention throughout: activations are ``[..., features]``
  and projections right-multiply (``x @ W`` with ``W [in, out]``).
* RoPE is interleaved-pair (paper Eq. 1): dims ``(2l, 2l+1)`` form the
  l-th complex plane with frequency ``theta ** (-2l/d)``.
* KV caches are padded to ``max_seq`` and masked by position; decode
  carries them as explicit inputs/outputs (xla 0.1.6 has no donation,
  which makes the decode step cache-traffic-bound — exactly the effect
  TransMLA exploits).
* Parameter "trees" are dicts; the canonical flat ordering consumed by
  the Rust side is given by the ``*_KEYS`` lists and recorded in
  ``artifacts/manifest.json``.
"""

import math

import jax
import jax.numpy as jnp

from .kernels.gqa_attn import gqa_decode_attention
from .kernels.mla_attn import mla_absorbed_decode_attention

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Parameter orderings (the ABI between aot.py and the Rust coordinator).
# ---------------------------------------------------------------------------

GQA_KEYS = [
    "embed",     # [V, D]
    "wq",        # [L, D, h*d]
    "wk",        # [L, D, g*d]
    "wv",        # [L, D, g*d]
    "wo",        # [L, h*d, D]
    "ln1",       # [L, D]
    "w_gate",    # [L, D, F]
    "w_up",      # [L, D, F]
    "w_down",    # [L, F, D]
    "ln2",       # [L, D]
    "ln_f",      # [D]
    "lm_head",   # [D, V]
]

# Absorbed (serving) MLA — Eq. 10 paradigm, W^UK folded into Q,
# W^UV folded into O. `rope_freqs` carries the (possibly FreqFolded)
# frequency schedule of the decoupled-RoPE head.
MLA_ABS_KEYS = [
    "embed",      # [V, D]
    "wq_rope",    # [L, h, D, dr]
    "wq_lat",     # [L, h, D, r]
    "w_dkv",      # [L, D, r]
    "w_krope",    # [L, D, dr]
    "wo_abs",     # [L, h, r, D]
    "ln1",        # [L, D]
    "w_gate",     # [L, D, F]
    "w_up",       # [L, D, F]
    "w_down",     # [L, F, D]
    "ln2",        # [L, D]
    "ln_f",       # [D]
    "lm_head",    # [D, V]
    "rope_freqs", # [dr/2]
]

# Trainable (fine-tuning) MLA — Eq. 9 paradigm: latent is up-projected to
# per-head keys/values, queries keep full rank.
MLA_TRAIN_KEYS = [
    "embed",      # [V, D]
    "wq",         # [L, D, h*d]
    "wqr",        # [L, h, d, dr]   per-head RoPE-query mixer (P_i^T)
    "w_dkv",      # [L, D, r]
    "w_krope",    # [L, D, dr]
    "w_uk",       # [L, h, r, d]    latent -> per-head NoPE key (U_i^T)
    "w_uv",       # [L, h, r, d]    latent -> per-head value    (V_i^T)
    "wo",         # [L, h*d, D]
    "ln1",
    "w_gate",
    "w_up",
    "w_down",
    "ln2",
    "ln_f",
    "lm_head",
    "rope_freqs", # [dr/2] (stop-gradient: structural, not trained)
]

# Merged/rotated analysis form (Sec. 4.1-4.2): one big key head, per-head
# query mixers, maskable per-pair RoPE with an explicit frequency schedule.
MERGED_KEYS = [
    "embed",      # [V, D]
    "wqm",        # [L, h, D, g*d]  fused W^Q_i @ A_i^T
    "wk",         # [L, D, g*d]     (rotated)
    "wv",         # [L, D, g*d]
    "wo",         # [L, h*d, D]
    "ln1",
    "w_gate",
    "w_up",
    "w_down",
    "ln2",
    "ln_f",
    "lm_head",
    "rope_freqs", # [g*d/2] per-pair frequency schedule (FreqFold-aware)
    "rope_mask",  # [g*d]   1.0 = keep RoPE on this dim, 0.0 = NoPE
]


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def default_freqs(n, theta=10000.0):
    """Frequency schedule for an n-dim RoPE head (n/2 pairs)."""
    l = jnp.arange(n // 2, dtype=jnp.float32)
    return theta ** (-2.0 * l / n)


def rope_apply(x, positions, freqs):
    """Interleaved-pair RoPE (paper Eq. 1).

    x: [..., n] (n even), positions: broadcastable to x[..., 0] shape,
    freqs: [n/2].
    """
    xe = x[..., 0::2]
    xo = x[..., 1::2]
    ang = positions[..., None].astype(jnp.float32) * freqs
    c, s = jnp.cos(ang), jnp.sin(ang)
    oe = xe * c - xo * s
    oo = xe * s + xo * c
    return jnp.stack([oe, oo], axis=-1).reshape(x.shape)


def rope_apply_masked(x, positions, freqs, mask):
    """RoPE applied only where mask==1 (dims with mask==0 become NoPE)."""
    return rope_apply(x, positions, freqs) * mask + x * (1.0 - mask)


def causal_mask(t):
    i = jnp.arange(t)
    return i[:, None] >= i[None, :]  # [T(query), T(key)]


def masked_softmax_2d(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def logits_from(x, params):
    return rmsnorm(x, params["ln_f"]) @ params["lm_head"]


def _layer_params(params, keys):
    """Slice the per-layer stacked arrays into a scan-compatible pytree."""
    return tuple(params[k] for k in keys)


# ---------------------------------------------------------------------------
# GQA model
# ---------------------------------------------------------------------------

GQA_LAYER = ("wq", "wk", "wv", "wo", "ln1", "w_gate", "w_up", "w_down", "ln2")


def gqa_prefill(params, tokens, cfg):
    """Full forward over [B, T=max_seq] tokens.

    Returns (logits [B,T,V], k_cache [L,B,T,g,d] (post-RoPE),
    v_cache [L,B,T,g,d]).
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    b, t = tokens.shape
    freqs = default_freqs(d, cfg.rope_theta)
    pos = jnp.arange(t, dtype=jnp.int32)
    cmask = causal_mask(t)
    scale = 1.0 / math.sqrt(d)

    x = params["embed"][tokens]

    def body(x, layer):
        wq, wk, wv, wo, ln1, wg, wu, wd, ln2 = layer
        hn = rmsnorm(x, ln1)
        q = (hn @ wq).reshape(b, t, h, d)
        k = (hn @ wk).reshape(b, t, g, d)
        v = (hn @ wv).reshape(b, t, g, d)
        qr = rope_apply(q, pos[None, :, None], freqs)
        kr = rope_apply(k, pos[None, :, None], freqs)
        rep = h // g
        qg = qr.reshape(b, t, g, rep, d)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qg, kr) * scale
        probs = masked_softmax_2d(scores, cmask[None, None, None])
        o = jnp.einsum("bgrst,btgd->bsgrd", probs, v).reshape(b, t, h * d)
        x = x + o @ wo
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, (kr, v)

    x, (ks, vs) = jax.lax.scan(body, x, _layer_params(params, GQA_LAYER))
    return logits_from(x, params), ks, vs


def gqa_decode(params, token, pos, k_cache, v_cache, cfg):
    """One decode step. token [B] i32, pos [B] i32 (index to write),
    caches [L,B,T,g,d]. Returns (logits [B,V], k_cache', v_cache')."""
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    b = token.shape[0]
    freqs = default_freqs(d, cfg.rope_theta)
    scale = 1.0 / math.sqrt(d)

    x = params["embed"][token]

    def body(x, layer):
        (wq, wk, wv, wo, ln1, wg, wu, wd, ln2), (kc, vc) = layer
        hn = rmsnorm(x, ln1)
        q = (hn @ wq).reshape(b, h, d)
        k = (hn @ wk).reshape(b, g, d)
        v = (hn @ wv).reshape(b, g, d)
        qr = rope_apply(q, pos[:, None], freqs)
        kr = rope_apply(k, pos[:, None], freqs)
        kc = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n[None], (p, 0, 0))
        )(kc, kr, pos)
        vc = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n[None], (p, 0, 0))
        )(vc, v, pos)
        o = gqa_decode_attention(qr, kc, vc, pos, scale=scale)
        x = x + o.reshape(b, h * d) @ wo
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, (kc, vc)

    layers = (_layer_params(params, GQA_LAYER), (k_cache, v_cache))
    x, (kc, vc) = jax.lax.scan(body, x, layers)
    return logits_from(x, params), kc, vc


def gqa_calib(params, tokens, cfg):
    """Calibration forward: returns pre-RoPE keys / values / queries.

    (k_pre [L,B,T,g*d], v [L,B,T,g*d], q_pre [L,B,T,h*d]).
    Pre-RoPE is the right statistic for RoRoPE: per-frequency cross-head
    covariance summed over (real, imag) is exactly RoPE-invariant.
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    b, t = tokens.shape
    freqs = default_freqs(d, cfg.rope_theta)
    pos = jnp.arange(t, dtype=jnp.int32)
    cmask = causal_mask(t)
    scale = 1.0 / math.sqrt(d)
    x = params["embed"][tokens]

    def body(x, layer):
        wq, wk, wv, wo, ln1, wg, wu, wd, ln2 = layer
        hn = rmsnorm(x, ln1)
        q = hn @ wq
        k = hn @ wk
        v = hn @ wv
        q4 = rope_apply(q.reshape(b, t, h, d), pos[None, :, None], freqs)
        k4 = rope_apply(k.reshape(b, t, g, d), pos[None, :, None], freqs)
        rep = h // g
        qg = q4.reshape(b, t, g, rep, d)
        scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k4) * scale
        probs = masked_softmax_2d(scores, cmask[None, None, None])
        o = jnp.einsum(
            "bgrst,btgd->bsgrd", probs, v.reshape(b, t, g, d)
        ).reshape(b, t, h * d)
        x = x + o @ wo
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, (k, v, q)

    _, (ks, vs, qs) = jax.lax.scan(body, x, _layer_params(params, GQA_LAYER))
    return ks, vs, qs


# ---------------------------------------------------------------------------
# Merged / rotated analysis model (Sec. 4.1 + 4.2)
# ---------------------------------------------------------------------------

MERGED_LAYER = ("wqm", "wk", "wv", "wo", "ln1", "w_gate", "w_up", "w_down", "ln2")


def merged_prefill(params, tokens, cfg):
    """Forward of the merged-single-key-head form with maskable RoPE.

    Scores: RoPE_masked(A_i q_i) . RoPE_masked(k_merged) / sqrt(d); the
    rotation Q is pre-folded into wk / wqm by the converter. Supports
    RoRoPE, FreqFold (via rope_freqs) and MHA2MLA partial-RoPE (via
    rope_mask) evaluation — Figure 2b. Returns logits [B,T,V].
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    b, t = tokens.shape
    freqs = params["rope_freqs"]
    mask = params["rope_mask"]
    pos = jnp.arange(t, dtype=jnp.int32)
    cmask = causal_mask(t)
    scale = 1.0 / math.sqrt(d)
    x = params["embed"][tokens]

    def body(x, layer):
        wqm, wk, wv, wo, ln1, wg, wu, wd, ln2 = layer
        hn = rmsnorm(x, ln1)
        qm = jnp.einsum("btD,hDg->bthg", hn, wqm)       # [B,T,h,g*d]
        km = hn @ wk                                     # [B,T,g*d]
        v = (hn @ wv).reshape(b, t, g, d)
        qmr = rope_apply_masked(qm, pos[None, :, None], freqs, mask)
        kmr = rope_apply_masked(km, pos[None, :], freqs, mask)
        scores = jnp.einsum("bshg,btg->bhst", qmr, kmr) * scale
        probs = masked_softmax_2d(scores, cmask[None, None])
        rep = h // g
        pg = probs.reshape(b, g, rep, t, t)
        o = jnp.einsum("bgrst,btgd->bsgrd", pg, v).reshape(b, t, h * d)
        x = x + o @ wo
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, ()

    x, _ = jax.lax.scan(body, x, _layer_params(params, MERGED_LAYER))
    return logits_from(x, params)


# ---------------------------------------------------------------------------
# MLA — absorbed (serving) form
# ---------------------------------------------------------------------------

MLA_ABS_LAYER = (
    "wq_rope", "wq_lat", "w_dkv", "w_krope", "wo_abs",
    "ln1", "w_gate", "w_up", "w_down", "ln2",
)


def mla_prefill(params, tokens, cfg):
    """Absorbed-form full forward. Returns (logits [B,T,V],
    c_cache [L,B,T,r], kr_cache [L,B,T,dr] (post-RoPE))."""
    d = cfg.head_dim
    b, t = tokens.shape
    freqs = params["rope_freqs"]
    pos = jnp.arange(t, dtype=jnp.int32)
    cmask = causal_mask(t)
    scale = 1.0 / math.sqrt(d)
    x = params["embed"][tokens]

    def body(x, layer):
        wqr, wql, wdkv, wkr, woabs, ln1, wg, wu, wd, ln2 = layer
        hn = rmsnorm(x, ln1)
        q_rope = jnp.einsum("btD,hDe->bthe", hn, wqr)    # [B,T,h,dr]
        q_lat = jnp.einsum("btD,hDr->bthr", hn, wql)     # [B,T,h,r]
        c = hn @ wdkv                                    # [B,T,r]
        kr = rope_apply(hn @ wkr, pos[None, :], freqs)   # [B,T,dr]
        q_rope = rope_apply(q_rope, pos[None, :, None], freqs)
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat, c)
            + jnp.einsum("bshe,bte->bhst", q_rope, kr)
        ) * scale
        probs = masked_softmax_2d(scores, cmask[None, None])
        o = jnp.einsum("bhst,btr->bshr", probs, c)       # [B,T,h,r]
        x = x + jnp.einsum("bshr,hrD->bsD", o, woabs)
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, (c, kr)

    x, (cs, krs) = jax.lax.scan(body, x, _layer_params(params, MLA_ABS_LAYER))
    return logits_from(x, params), cs, krs


def mla_decode(params, token, pos, c_cache, kr_cache, cfg):
    """One absorbed-MLA decode step over the latent cache (Pallas L1 path).

    caches: c [L,B,T,r], kr [L,B,T,dr]. Returns (logits, c', kr')."""
    d = cfg.head_dim
    b = token.shape[0]
    freqs = params["rope_freqs"]
    scale = 1.0 / math.sqrt(d)
    x = params["embed"][token]

    def body(x, layer):
        (wqr, wql, wdkv, wkr, woabs, ln1, wg, wu, wd, ln2), (cc, krc) = layer
        hn = rmsnorm(x, ln1)
        q_rope = jnp.einsum("bD,hDe->bhe", hn, wqr)
        q_lat = jnp.einsum("bD,hDr->bhr", hn, wql)
        q_rope = rope_apply(q_rope, pos[:, None], freqs)
        c_new = hn @ wdkv                                 # [B,r]
        kr_new = rope_apply(hn @ wkr, pos, freqs)  # [B,dr], per-seq position
        cc = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n[None], (p, 0))
        )(cc, c_new, pos)
        krc = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n[None], (p, 0))
        )(krc, kr_new, pos)
        o = mla_absorbed_decode_attention(q_lat, q_rope, cc, krc, pos, scale=scale)
        x = x + jnp.einsum("bhr,hrD->bD", o, woabs)
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, (cc, krc)

    layers = (_layer_params(params, MLA_ABS_LAYER), (c_cache, kr_cache))
    x, (cc, krc) = jax.lax.scan(body, x, layers)
    return logits_from(x, params), cc, krc


# ---------------------------------------------------------------------------
# MLA — trainable (fine-tuning) form, Eq. 9 paradigm
# ---------------------------------------------------------------------------

MLA_TRAIN_LAYER = (
    "wq", "wqr", "w_dkv", "w_krope", "w_uk", "w_uv", "wo",
    "ln1", "w_gate", "w_up", "w_down", "ln2",
)


def mla_train_forward(params, tokens, cfg):
    """Trainable-form forward returning logits [B,T,V]."""
    h, d = cfg.n_heads, cfg.head_dim
    b, t = tokens.shape
    freqs = jax.lax.stop_gradient(params["rope_freqs"])
    pos = jnp.arange(t, dtype=jnp.int32)
    cmask = causal_mask(t)
    scale = 1.0 / math.sqrt(d)
    x = params["embed"][tokens]

    def body(x, layer):
        wq, wqr, wdkv, wkr, wuk, wuv, wo, ln1, wg, wu, wd, ln2 = layer
        hn = rmsnorm(x, ln1)
        q = (hn @ wq).reshape(b, t, h, d)
        c = hn @ wdkv                                     # [B,T,r]
        kr = rope_apply(hn @ wkr, pos[None, :], freqs)    # [B,T,dr]
        q_rope = rope_apply(
            jnp.einsum("bthd,hde->bthe", q, wqr), pos[None, :, None], freqs
        )
        k_c = jnp.einsum("btr,hrd->bthd", c, wuk)         # per-head NoPE keys
        v = jnp.einsum("btr,hrd->bthd", c, wuv)           # per-head values
        scores = (
            jnp.einsum("bshd,bthd->bhst", q, k_c)
            + jnp.einsum("bshe,bte->bhst", q_rope, kr)
        ) * scale
        probs = masked_softmax_2d(scores, cmask[None, None])
        o = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, t, h * d)
        x = x + o @ wo
        x = x + swiglu(rmsnorm(x, ln2), wg, wu, wd)
        return x, ()

    x, _ = jax.lax.scan(body, x, _layer_params(params, MLA_TRAIN_LAYER))
    return logits_from(x, params)


# ---------------------------------------------------------------------------
# Training (next-byte cross-entropy + Adam)
# ---------------------------------------------------------------------------

def lm_loss(logits, tokens):
    """Causal LM loss: predict tokens[:,1:] from positions [:, :-1]."""
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_step(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_k = b1 * m[k] + (1 - b1) * g
        v_k = b2 * v[k] + (1 - b2) * g * g
        mh = m_k / (1 - b1 ** step)
        vh = v_k / (1 - b2 ** step)
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
        new_m[k] = m_k
        new_v[k] = v_k
    return new_p, new_m, new_v


def make_train_step(forward, cfg):
    """Generic Adam train step over a forward(params, tokens, cfg)->logits."""

    def train_step(params, m, v, step, lr, tokens):
        def loss_fn(p):
            return lm_loss(forward(p, tokens, cfg), tokens)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_p, new_m, new_v = adam_step(params, grads, m, v, step, lr)
        return new_p, new_m, new_v, loss

    return train_step


def gqa_forward_logits(params, tokens, cfg):
    return gqa_prefill(params, tokens, cfg)[0]


# ---------------------------------------------------------------------------
# Initialization (python-side; the Rust pipeline has its own mirrored init)
# ---------------------------------------------------------------------------

def init_gqa_params(key, cfg, dtype=jnp.float32):
    h, g, d, dm, f, lyr, vcb = (
        cfg.n_heads, cfg.n_kv_groups, cfg.head_dim,
        cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab,
    )

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape) * scale).astype(dtype)

    ks = jax.random.split(key, 16)
    s = 0.02
    return {
        "embed": nrm(ks[0], (vcb, dm), s),
        "wq": nrm(ks[1], (lyr, dm, h * d), s),
        "wk": nrm(ks[2], (lyr, dm, g * d), s),
        "wv": nrm(ks[3], (lyr, dm, g * d), s),
        "wo": nrm(ks[4], (lyr, h * d, dm), s),
        "ln1": jnp.ones((lyr, dm), dtype),
        "w_gate": nrm(ks[5], (lyr, dm, f), s),
        "w_up": nrm(ks[6], (lyr, dm, f), s),
        "w_down": nrm(ks[7], (lyr, f, dm), s),
        "ln2": jnp.ones((lyr, dm), dtype),
        "ln_f": jnp.ones((dm,), dtype),
        "lm_head": nrm(ks[8], (dm, vcb), s),
    }


def mla_abs_shapes(cfg, r):
    h, d, dm, f, lyr, vcb = (
        cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.d_ff,
        cfg.n_layers, cfg.vocab,
    )
    return {
        "embed": (vcb, dm),
        "wq_rope": (lyr, h, dm, d),
        "wq_lat": (lyr, h, dm, r),
        "w_dkv": (lyr, dm, r),
        "w_krope": (lyr, dm, d),
        "wo_abs": (lyr, h, r, dm),
        "ln1": (lyr, dm),
        "w_gate": (lyr, dm, f),
        "w_up": (lyr, dm, f),
        "w_down": (lyr, f, dm),
        "ln2": (lyr, dm),
        "ln_f": (dm,),
        "lm_head": (dm, vcb),
        "rope_freqs": (d // 2,),
    }


def mla_train_shapes(cfg, r):
    h, d, dm, f, lyr, vcb = (
        cfg.n_heads, cfg.head_dim, cfg.d_model, cfg.d_ff,
        cfg.n_layers, cfg.vocab,
    )
    return {
        "embed": (vcb, dm),
        "wq": (lyr, dm, h * d),
        "wqr": (lyr, h, d, d),
        "w_dkv": (lyr, dm, r),
        "w_krope": (lyr, dm, d),
        "w_uk": (lyr, h, r, d),
        "w_uv": (lyr, h, r, d),
        "wo": (lyr, h * d, dm),
        "ln1": (lyr, dm),
        "w_gate": (lyr, dm, f),
        "w_up": (lyr, dm, f),
        "w_down": (lyr, f, dm),
        "ln2": (lyr, dm),
        "ln_f": (dm,),
        "lm_head": (dm, vcb),
        "rope_freqs": (d // 2,),
    }


def gqa_shapes(cfg):
    h, g, d, dm, f, lyr, vcb = (
        cfg.n_heads, cfg.n_kv_groups, cfg.head_dim, cfg.d_model,
        cfg.d_ff, cfg.n_layers, cfg.vocab,
    )
    return {
        "embed": (vcb, dm),
        "wq": (lyr, dm, h * d),
        "wk": (lyr, dm, g * d),
        "wv": (lyr, dm, g * d),
        "wo": (lyr, h * d, dm),
        "ln1": (lyr, dm),
        "w_gate": (lyr, dm, f),
        "w_up": (lyr, dm, f),
        "w_down": (lyr, f, dm),
        "ln2": (lyr, dm),
        "ln_f": (dm,),
        "lm_head": (dm, vcb),
    }


def merged_shapes(cfg):
    sh = dict(gqa_shapes(cfg))
    h, g, d, dm, lyr = (
        cfg.n_heads, cfg.n_kv_groups, cfg.head_dim, cfg.d_model, cfg.n_layers,
    )
    del sh["wq"]
    sh["wqm"] = (lyr, h, dm, g * d)
    sh["rope_freqs"] = (g * d // 2,)
    sh["rope_mask"] = (g * d,)
    return sh
