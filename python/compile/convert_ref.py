"""Reference implementation of the TransMLA conversion pipeline (numpy).

This is the paper's Section 4 as executable math, used as the oracle for
the production Rust converter (``rust/src/convert``) and by the python
test-suite's invariance checks:

  1. merge      — all KV heads become one big latent head; per-query-head
                  mixers ``M_i`` start as block selectors (Sec. 4.1).
  2. RoRoPE     — per-frequency cross-head PCA rotation that commutes with
                  RoPE (Eq. 19 / Appendix B), concentrating key energy into
                  head 0 (Sec. 4.2).
  3. FreqFold   — fold M adjacent frequencies into one representative so
                  PCA acts on M*g-dim segments (Appendix C). Approximate.
  4. BKV        — balance NoPE-key vs value norms by alpha (Eq. 20).
  5. joint PCA  — low-rank latent for [k_nope/alpha ; v] (Appendix D),
                  activation-based ("wx") or weight-based ("w").
  6. absorb     — fold W^UK into Q and W^UV into O (Eq. 10).

Also implements the MHA2MLA baseline (Ji et al. 2025): per-head norm-based
RoPE-dim selection + unbalanced weight-SVD compression.

All math is float64 numpy for a clean oracle; the exported params are cast
to float32 by the caller.
"""

import numpy as np


# ---------------------------------------------------------------------------
# Small helpers
# ---------------------------------------------------------------------------

def eigh_desc(c):
    """Symmetric eigendecomposition, eigenvalues descending."""
    w, v = np.linalg.eigh(c)
    order = np.argsort(w)[::-1]
    return w[order], v[:, order]


def selector_mixers(cfg):
    """Initial per-query-head mixers M_i [h, d, g*d]: q-head i sees only its
    KV group's block (Sec. 4.1 W^UK initialization)."""
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    m = np.zeros((h, d, g * d))
    rep = h // g
    for i in range(h):
        j = i // rep
        m[i, :, j * d:(j + 1) * d] = np.eye(d)
    return m


def merged_freqs(cfg):
    """Per-pair frequency schedule of the merged key head [g*d/2]."""
    g, d = cfg.n_kv_groups, cfg.head_dim
    l = np.arange(d // 2, dtype=np.float64)
    base = cfg.rope_theta ** (-2.0 * l / d)
    return np.tile(base, g)


def pair_index(head, l, d):
    """Merged pair index of frequency-pair l in head chunk `head`."""
    return head * (d // 2) + l


def real_dim(head, l, d):
    return head * d + 2 * l


# ---------------------------------------------------------------------------
# Step 1+2+3: RoRoPE (+FreqFold) rotation
# ---------------------------------------------------------------------------

def rorope_rotation(k_samples, cfg, fold=1):
    """Compute the big RoPE-commuting rotation Q [g*d, g*d] from pre-RoPE
    merged-key samples [N, g*d], plus the folded frequency schedule
    [g*d/2] and the permutation-aware layout described below.

    For each frequency group m (``fold`` adjacent frequencies), PCA is run
    over the 2*fold*g-dim (real+imag summed) cross-head segments; component
    c of group m is laid out at (head c//fold, freq-slot m*fold + c%fold),
    so head 0 collects the top `fold` components of every group.

    Returns (Q, new_freqs). Rotated merged keys are ``k @ Q.T``.
    """
    g, d = cfg.n_kv_groups, cfg.head_dim
    n_freq = d // 2
    assert n_freq % fold == 0, "fold must divide d/2"
    gd = g * d
    q_big = np.zeros((gd, gd))
    base = merged_freqs(cfg)[:n_freq]  # head-0 chunk schedule
    new_freqs_chunk = np.empty(n_freq)

    for m in range(n_freq // fold):
        ls = list(range(m * fold, (m + 1) * fold))
        # Sample matrix order: (l, head) pairs, real and imag stacked.
        re_cols = [real_dim(j, l, d) for l in ls for j in range(g)]
        im_cols = [c + 1 for c in re_cols]
        zr = k_samples[:, re_cols]
        zi = k_samples[:, im_cols]
        cmat = zr.T @ zr + zi.T @ zi  # RoPE-invariant covariance
        _, u = eigh_desc(cmat)        # [fold*g, fold*g], columns = comps
        # Component c -> (new head jc = c // fold, slot p = c % fold).
        for c in range(fold * g):
            jc, p = c // fold, c % fold
            l_new = m * fold + p
            rd_new = real_dim(jc, l_new, d)
            for idx, (l, j) in enumerate([(l, j) for l in ls for j in range(g)]):
                rd_old = real_dim(j, l, d)
                q_big[rd_new, rd_old] = u[idx, c]
                q_big[rd_new + 1, rd_old + 1] = u[idx, c]
        # Representative frequency for the whole group (first member).
        for l in ls:
            new_freqs_chunk[l] = base[m * fold]

    return q_big, np.tile(new_freqs_chunk, g)


def apply_rotation(wk, mixers, q_big):
    """Rotate the merged key space: wk [D, g*d] -> wk @ Q^T, and every
    mixer M_i [d, g*d] -> M_i @ Q^T (Eq. 19 both-sides rotation)."""
    return wk @ q_big.T, mixers @ q_big.T


# ---------------------------------------------------------------------------
# RoPE-removal masks (Figure 2b strategies)
# ---------------------------------------------------------------------------

def rorope_mask(cfg, keep_components, fold=1):
    """Keep RoPE on the top `keep_components` PCA components per frequency
    group (RoRoPE ordering: head-major after relayout)."""
    g, d = cfg.n_kv_groups, cfg.head_dim
    mask = np.zeros(g * d)
    n_freq = d // 2
    for m in range(n_freq // fold):
        for c in range(min(keep_components, fold * g)):
            jc, p = c // fold, c % fold
            l_new = m * fold + p
            rd = real_dim(jc, l_new, d)
            mask[rd] = 1.0
            mask[rd + 1] = 1.0
    return mask


def mha2mla_mask(cfg, k_samples, q_samples, keep_pairs_per_head):
    """MHA2MLA 'norm' strategy: per KV head, keep RoPE on the
    `keep_pairs_per_head` frequency pairs with the largest
    mean ||q_pair|| * ||k_pair|| (aggregated over the group's query heads).
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    rep = h // g
    n_freq = d // 2
    mask = np.zeros(g * d)
    for j in range(g):
        scores = np.zeros(n_freq)
        for l in range(n_freq):
            kc = k_samples[:, [real_dim(j, l, d), real_dim(j, l, d) + 1]]
            knorm = np.mean(np.linalg.norm(kc, axis=1))
            qnorm = 0.0
            for i in range(j * rep, (j + 1) * rep):
                qc = q_samples[:, [i * d + 2 * l, i * d + 2 * l + 1]]
                qnorm += np.mean(np.linalg.norm(qc, axis=1))
            scores[l] = knorm * qnorm
        keep = np.argsort(scores)[::-1][:keep_pairs_per_head]
        for l in keep:
            mask[real_dim(j, l, d)] = 1.0
            mask[real_dim(j, l, d) + 1] = 1.0
    return mask


# ---------------------------------------------------------------------------
# Step 4+5: Balanced joint low-rank PCA
# ---------------------------------------------------------------------------

def kv_balance_alpha(k_nope_samples, v_samples):
    """Eq. 20: alpha = E||k_nope|| / E||v||."""
    kn = np.mean(np.linalg.norm(k_nope_samples, axis=1))
    vn = np.mean(np.linalg.norm(v_samples, axis=1))
    return kn / max(vn, 1e-12)


def joint_lowrank_basis(k_nope_samples, v_samples, alpha, r, mode="wx",
                        wk_nope=None, wv=None):
    """PCA basis R [(n_k + n_v), r] for the balanced joint space.

    mode="wx": activation-based PCA (paper's choice, Fig. 3b "WX-based").
    mode="w" : weight-based PCA over the rows of [Wk_nope/alpha ; Wv]
               (Fig. 3b "W-based" ablation; requires wk_nope [D, n_k] and
               wv [D, n_v]).
    """
    if mode == "wx":
        z = np.concatenate([k_nope_samples / alpha, v_samples], axis=1)
        cmat = z.T @ z
    elif mode == "w":
        w = np.concatenate([wk_nope / alpha, wv], axis=1)  # [D, n_k+n_v]
        cmat = w.T @ w
    else:
        raise ValueError(mode)
    _, u = eigh_desc(cmat)
    return u[:, :r]


# ---------------------------------------------------------------------------
# Full per-layer conversion -> trainable MLA params
# ---------------------------------------------------------------------------

def convert_layer(wq, wk, wv, k_pre, q_pre, v_act, cfg, r, fold=1,
                  balance=True, pca_mode="wx", baseline=None,
                  keep_pairs_per_head=None):
    """Convert one GQA layer to trainable-MLA parameter blocks.

    wq [D, h*d], wk [D, g*d], wv [D, g*d];
    k_pre/q_pre/v_act: calibration activations [N, g*d] / [N, h*d] / [N, g*d].

    baseline=None     -> TransMLA (RoRoPE + FreqFold + BKV + joint PCA)
    baseline="mha2mla"-> norm-selected per-head partial RoPE + plain
                         weight-SVD, no balancing.

    Returns dict with keys wq, wqr [h,d,dr], w_dkv [D,r], w_krope [D,dr],
    w_uk [h,r,d], w_uv [h,r,d], rope_freqs [dr/2], plus diagnostics.
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    gd = g * d
    mixers = selector_mixers(cfg)

    if baseline is None:
        q_big, new_freqs = rorope_rotation(k_pre, cfg, fold=fold)
        wk_rot, mixers = apply_rotation(wk, mixers, q_big)
        k_rot = k_pre @ q_big.T
        rope_dims = np.zeros(gd, bool)
        rope_dims[:d] = True  # head 0 carries all positional info
        freqs_out = new_freqs[: d // 2]
    else:
        kp = keep_pairs_per_head
        if kp is None:
            kp = d // (2 * g)  # same total rope budget as TransMLA
        mask = mha2mla_mask(cfg, k_pre, q_pre, kp)
        wk_rot = wk
        k_rot = k_pre
        rope_dims = mask > 0.5
        # Per-pair schedule of the kept dims, in merged order.
        mf = merged_freqs(cfg)
        freqs_out = np.array(
            [mf[i // 2] for i in range(gd) if rope_dims[i] and i % 2 == 0]
        )

    nope_dims = ~rope_dims
    dr = int(rope_dims.sum())
    n_nope = gd - dr

    wk_rope = wk_rot[:, rope_dims]        # [D, dr]
    wk_nope = wk_rot[:, nope_dims]        # [D, n_nope]
    k_nope_act = k_rot[:, nope_dims]

    if balance and baseline is None:
        alpha = kv_balance_alpha(k_nope_act, v_act)
    else:
        alpha = 1.0

    rr = min(r, n_nope + gd)
    rbasis = joint_lowrank_basis(
        k_nope_act, v_act, alpha, rr,
        mode=("w" if baseline == "mha2mla" else pca_mode),
        wk_nope=wk_nope, wv=wv,
    )
    r_k = rbasis[:n_nope, :]              # [n_nope, r]
    r_v = rbasis[n_nope:, :]              # [g*d, r]

    w_dkv = np.concatenate([wk_nope / alpha, wv], axis=1) @ rbasis  # [D, r]

    # Per-head blocks.
    wqr = np.empty((h, d, dr))
    w_uk = np.empty((h, rr, d))
    w_uv = np.empty((h, rr, d))
    rep = h // g
    for i in range(h):
        m_i = mixers[i]                   # [d, g*d]
        wqr[i] = m_i[:, rope_dims]        # q_rope_i = q_i @ wqr_i
        b_i = m_i[:, nope_dims]           # [d, n_nope]
        w_uk[i] = alpha * (b_i @ r_k).T   # [r, d]
        j = i // rep
        w_uv[i] = r_v[j * d:(j + 1) * d, :].T  # [r, d]

    return {
        "wq": wq,
        "wqr": wqr,
        "w_dkv": w_dkv,
        "w_krope": wk_rope,
        "w_uk": w_uk,
        "w_uv": w_uv,
        "rope_freqs": freqs_out,
        "alpha": alpha,
        "dr": dr,
    }


def absorb_layer(lp, wo):
    """Fold W^UK into Q and W^UV into O (Eq. 10). wo [h*d, D].

    Returns wq_rope [h,D,dr], wq_lat [h,D,r], wo_abs [h,r,D]."""
    h, d, dr = lp["wqr"].shape
    rr = lp["w_uk"].shape[1]
    dm = lp["wq"].shape[0]
    wq_rope = np.empty((h, dm, dr))
    wq_lat = np.empty((h, dm, rr))
    wo_abs = np.empty((h, rr, wo.shape[1]))
    for i in range(h):
        wq_i = lp["wq"][:, i * d:(i + 1) * d]     # [D, d]
        wq_rope[i] = wq_i @ lp["wqr"][i]          # [D, dr]
        wq_lat[i] = wq_i @ lp["w_uk"][i].T        # [D, r]
        wo_abs[i] = lp["w_uv"][i] @ wo[i * d:(i + 1) * d, :]  # [r, D]
    return wq_rope, wq_lat, wo_abs


# ---------------------------------------------------------------------------
# Whole-model conversion
# ---------------------------------------------------------------------------

def convert_model(gqa_params, calib, cfg, r, fold=1, balance=True,
                  pca_mode="wx", baseline=None, keep_pairs_per_head=None):
    """Convert a full GQA parameter dict (numpy arrays, layouts as in
    model.GQA_KEYS) into trainable-MLA and absorbed-MLA dicts.

    calib: (k_pre [L,N,g*d], v [L,N,g*d], q_pre [L,N,h*d]).
    Returns (mla_train_params, mla_abs_params, diag).
    """
    lyr = cfg.n_layers
    k_pre, v_act, q_pre = calib
    layers = []
    for l in range(lyr):
        layers.append(
            convert_layer(
                gqa_params["wq"][l], gqa_params["wk"][l], gqa_params["wv"][l],
                k_pre[l], q_pre[l], v_act[l], cfg, r, fold=fold,
                balance=balance, pca_mode=pca_mode, baseline=baseline,
                keep_pairs_per_head=keep_pairs_per_head,
            )
        )

    def stack(key):
        return np.stack([lp[key] for lp in layers])

    train = {
        "embed": gqa_params["embed"],
        "wq": gqa_params["wq"],
        "wqr": stack("wqr"),
        "w_dkv": stack("w_dkv"),
        "w_krope": stack("w_krope"),
        "w_uk": stack("w_uk"),
        "w_uv": stack("w_uv"),
        "wo": gqa_params["wo"],
        "ln1": gqa_params["ln1"],
        "w_gate": gqa_params["w_gate"],
        "w_up": gqa_params["w_up"],
        "w_down": gqa_params["w_down"],
        "ln2": gqa_params["ln2"],
        "ln_f": gqa_params["ln_f"],
        "lm_head": gqa_params["lm_head"],
        "rope_freqs": layers[0]["rope_freqs"],
    }

    wq_rope, wq_lat, wo_abs = [], [], []
    for l in range(lyr):
        a, b, c = absorb_layer(layers[l], gqa_params["wo"][l])
        wq_rope.append(a)
        wq_lat.append(b)
        wo_abs.append(c)

    absorbed = {
        "embed": gqa_params["embed"],
        "wq_rope": np.stack(wq_rope),
        "wq_lat": np.stack(wq_lat),
        "w_dkv": train["w_dkv"],
        "w_krope": train["w_krope"],
        "wo_abs": np.stack(wo_abs),
        "ln1": gqa_params["ln1"],
        "w_gate": gqa_params["w_gate"],
        "w_up": gqa_params["w_up"],
        "w_down": gqa_params["w_down"],
        "ln2": gqa_params["ln2"],
        "ln_f": gqa_params["ln_f"],
        "lm_head": gqa_params["lm_head"],
        "rope_freqs": train["rope_freqs"],
    }
    diag = {"alphas": [lp["alpha"] for lp in layers]}
    return train, absorbed, diag


def merged_params_from(gqa_params, cfg, q_big=None, freqs=None, mask=None):
    """Build merged-form params (model.MERGED_KEYS) from GQA params, with
    optional rotation / frequency schedule / rope mask — the Fig. 2b model.
    """
    h, g, d = cfg.n_heads, cfg.n_kv_groups, cfg.head_dim
    gd = g * d
    mixers = selector_mixers(cfg)
    wk = gqa_params["wk"].copy()
    lyr = cfg.n_layers
    wqm = np.empty((lyr, h, gqa_params["wq"].shape[1], gd))
    for l in range(lyr):
        mx = mixers
        wk_l = gqa_params["wk"][l]
        if q_big is not None:
            wk_l, mx = apply_rotation(wk_l, mixers, q_big[l])
        wk[l] = wk_l
        for i in range(h):
            wqm[l, i] = gqa_params["wq"][l][:, i * d:(i + 1) * d] @ mx[i]
    out = {k: gqa_params[k] for k in
           ("embed", "wv", "wo", "ln1", "w_gate", "w_up", "w_down",
            "ln2", "ln_f", "lm_head")}
    out["wqm"] = wqm
    out["wk"] = wk
    out["rope_freqs"] = merged_freqs(cfg) if freqs is None else freqs
    out["rope_mask"] = np.ones(gd) if mask is None else mask
    return out
