"""Pure-jnp oracles for the Pallas decode-attention kernels.

These are the CORE correctness references: every Pallas kernel in this
package must match its oracle here to float tolerance (pytest + hypothesis
sweep shapes and dtypes in ``python/tests/test_kernel.py``).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def masked_softmax(scores, pos):
    """Softmax over the last axis with positions > pos masked out.

    scores: [..., T]; pos broadcastable to scores (last valid cache index).
    """
    t = scores.shape[-1]
    idx = jnp.arange(t)
    mask = idx <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def gqa_decode_attention_ref(q, k_cache, v_cache, pos, scale):
    """Grouped-query decode attention over a padded cache.

    q:        [B, h, d]     query for the new token (RoPE already applied)
    k_cache:  [B, T, g, d]  keys   (positions > pos are padding)
    v_cache:  [B, T, g, d]  values
    pos:      [B] int32     index of the newest valid entry per sequence
    returns:  [B, h, d]
    """
    b, h, d = q.shape
    g = k_cache.shape[2]
    rep = h // g
    qg = q.reshape(b, g, rep, d)
    scores = jnp.einsum("bgrd,btgd->bgrt", qg, k_cache) * scale
    probs = masked_softmax(scores, pos[:, None, None, None])
    out = jnp.einsum("bgrt,btgd->bgrd", probs, v_cache)
    return out.reshape(b, h, d)


def mla_absorbed_decode_attention_ref(q_lat, q_rope, c_cache, kr_cache, pos, scale):
    """Absorbed-MLA decode attention (the paper's Eq. 10 inference paradigm).

    Every query head attends over the SAME latent cache (MQA-like):
      score_j = q_lat . c_j + q_rope . k_rope_j
      out_i   = sum_j softmax(score)_j * c_j        (latent-space output)

    q_lat:    [B, h, r]     latent-absorbed queries
    q_rope:   [B, h, dr]    decoupled-RoPE queries (RoPE already applied)
    c_cache:  [B, T, r]     latent KV cache
    kr_cache: [B, T, dr]    shared RoPE-key cache (RoPE already applied)
    pos:      [B] int32
    returns:  [B, h, r]
    """
    scores = (
        jnp.einsum("bhr,btr->bht", q_lat, c_cache)
        + jnp.einsum("bhd,btd->bht", q_rope, kr_cache)
    ) * scale
    probs = masked_softmax(scores, pos[:, None, None])
    return jnp.einsum("bht,btr->bhr", probs, c_cache)
