"""Pallas kernel: absorbed-MLA decode attention — the paper's hot path.

This is the Eq. 10 inference paradigm: after the Absorb operation the
latent cache ``c`` acts as one shared big KV head, every query head scores
against it directly, and the attention output stays in latent space (the
per-head ``W^UV`` up-projection is folded into ``W^O`` outside the kernel).

TPU shaping notes (the kernel itself is executed with ``interpret=True``
on this CPU testbed — see DESIGN.md §Hardware-Adaptation):
  * one program per sequence; the whole latent stripe ``[T, r + dr]``
    fits VMEM for every exported rank (T=512, r<=192 -> <=448 KiB f32),
    so no double-buffered HBM streaming is needed at this scale;
  * both matmuls are ``[h, r] x [r, T]`` and ``[h, T] x [T, r]`` —
    MXU-systolic-friendly, with h the (small) sublane dimension;
  * scores for the latent and RoPE parts are fused into one pass so the
    cache stripe is read exactly once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(ql_ref, qr_ref, c_ref, kr_ref, pos_ref, o_ref, *, scale):
    # ql_ref: [h, r]   latent-absorbed queries
    # qr_ref: [h, dr]  decoupled-RoPE queries (RoPE applied)
    # c_ref:  [T, r]   latent cache stripe
    # kr_ref: [T, dr]  shared RoPE-key stripe
    ql = ql_ref[...]
    qr = qr_ref[...]
    c = c_ref[...]
    kr = kr_ref[...]
    pos = pos_ref[0]

    # Fused content + positional scores (paper Eq. 10 numerator).
    scores = (jnp.dot(ql, c.T) + jnp.dot(qr, kr.T)) * scale  # [h, T]
    t = scores.shape[-1]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1) <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(probs, c)  # [h, r] — output stays latent


def mla_absorbed_decode_attention(
    q_lat, q_rope, c_cache, kr_cache, pos, *, scale, interpret=True
):
    """Absorbed-MLA decode attention over the latent KV cache.

    q_lat:    [B, h, r]
    q_rope:   [B, h, dr]
    c_cache:  [B, T, r]
    kr_cache: [B, T, dr]
    pos:      [B] int32
    returns:  [B, h, r]
    """
    b, h, r = q_lat.shape
    dr = q_rope.shape[-1]
    t = c_cache.shape[1]

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, h, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, h, dr), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, t, dr), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((None, h, r), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, r), q_lat.dtype),
        interpret=interpret,
    )(q_lat, q_rope, c_cache, kr_cache, pos)
