"""Pallas kernel: GQA decode attention (the baseline hot path).

Lowered with ``interpret=True`` so the resulting HLO runs on the CPU PJRT
plugin (real-TPU lowering emits a Mosaic custom-call the CPU client cannot
execute). The BlockSpec structure is nevertheless written the way a TPU
kernel would be tiled: one program per (batch, group) pair, with the
group's key/value stripe of the cache staged through VMEM and the
``[rep, d] x [d, T]`` score matmul shaped for the MXU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale):
    # q_ref: [rep, d]  queries of the heads sharing this KV group
    # k_ref: [T, d]    this group's key stripe
    # v_ref: [T, d]    this group's value stripe
    # pos_ref: [1]     newest valid cache index for this sequence
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    pos = pos_ref[0]

    scores = jnp.dot(q, k.T) * scale  # [rep, T] — MXU-shaped matmul
    t = scores.shape[-1]
    mask = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1) <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(probs, v)  # [rep, d]


def gqa_decode_attention(q, k_cache, v_cache, pos, *, scale, interpret=True):
    """Decode-step attention for a GQA/MHA model over a padded KV cache.

    q:       [B, h, d] (RoPE already applied)
    k_cache: [B, T, g, d]
    v_cache: [B, T, g, d]
    pos:     [B] int32
    returns: [B, h, d]
    """
    b, h, d = q.shape
    t, g = k_cache.shape[1], k_cache.shape[2]
    rep = h // g
    qg = q.reshape(b, g, rep, d)

    grid = (b, g)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, rep, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, t, None, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, t, None, d), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((None, None, rep, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, rep, d), q.dtype),
        interpret=interpret,
    )(qg, k_cache, v_cache, pos)
    return out.reshape(b, h, d)
