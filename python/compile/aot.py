"""AOT export: lower every L2 entry point to HLO *text* + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.

Run once via ``make artifacts``; the Rust coordinator then needs no Python.

Usage: (cd python && python -m compile.aot --out ../artifacts)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (
    CONFIGS,
    DECODE_BATCHES,
    PREFILL_BATCH,
    SWEEP_RANKS,
    TABLE1_RANKS,
    TRAIN_BATCH,
    TRAIN_SEQ,
)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def dict_specs(shapes):
    return {k: spec(v) for k, v in shapes.items()}


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.entries = []

    def export(self, name, fn, flat_specs, meta):
        """Lower fn(*flat_args) and write `<name>.hlo.txt`."""
        lowered = jax.jit(fn, keep_unused=True).lower(*flat_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        inputs = [
            {"dtype": str(s.dtype), "shape": list(s.shape)} for s in flat_specs
        ]
        out_shapes = jax.eval_shape(fn, *flat_specs)
        outputs = [
            {"dtype": str(s.dtype), "shape": list(s.shape)}
            for s in jax.tree_util.tree_leaves(out_shapes)
        ]
        entry = dict(meta)
        entry.update(name=name, file=fname, inputs=inputs, outputs=outputs)
        self.entries.append(entry)
        print(f"  wrote {fname} ({len(text)/1e6:.2f} MB, "
              f"{len(inputs)} in / {len(outputs)} out)")


def flatten_call(fn, keys, shapes, extra_specs, cfg):
    """Build (wrapper, flat_specs) where the wrapper takes the params in
    `keys` order followed by the extra inputs."""
    n = len(keys)

    def wrapper(*args):
        params = {k: a for k, a in zip(keys, args[:n])}
        return fn(params, *args[n:])

    flat = [spec(shapes[k]) for k in keys] + list(extra_specs)
    return wrapper, flat


def train_wrapper(forward, keys, shapes, cfg, b, t):
    """Adam train step over flat args: params*, m*, v*, step, lr, tokens."""
    n = len(keys)
    step_fn = M.make_train_step(forward, cfg)

    def wrapper(*args):
        p = {k: a for k, a in zip(keys, args[:n])}
        m = {k: a for k, a in zip(keys, args[n:2 * n])}
        v = {k: a for k, a in zip(keys, args[2 * n:3 * n])}
        step, lr, tokens = args[3 * n:]
        new_p, new_m, new_v, loss = step_fn(p, m, v, step, lr, tokens)
        flat = [new_p[k] for k in keys] + [new_m[k] for k in keys] + \
               [new_v[k] for k in keys] + [loss]
        return tuple(flat)

    flat = (
        [spec(shapes[k]) for k in keys] * 3
        + [spec((), F32), spec((), F32), spec((b, t), I32)]
    )
    return wrapper, flat


def export_config(ex, cfg, table1_ranks, sweep_ranks, full=True):
    name = cfg.name
    t = cfg.max_seq
    bp = PREFILL_BATCH
    print(f"[{name}] g={cfg.n_kv_groups} kv/token={cfg.kv_per_token}")

    base_meta = {"config": cfg.to_dict(), "arch": None, "rank": None,
                 "batch": None, "seq": t}

    # --- GQA baseline ---
    gsh = M.gqa_shapes(cfg)
    fn, flat = flatten_call(
        lambda p, tok: M.gqa_prefill(p, tok, cfg),
        M.GQA_KEYS, gsh, [spec((bp, t), I32)], cfg)
    ex.export(f"{name}_gqa_prefill", fn, flat,
              {**base_meta, "arch": "gqa", "kind": "prefill", "batch": bp,
               "params": M.GQA_KEYS})

    for b in (DECODE_BATCHES if full else [max(DECODE_BATCHES)]):
        l, g, d = cfg.n_layers, cfg.n_kv_groups, cfg.head_dim
        extras = [
            spec((b,), I32), spec((b,), I32),
            spec((l, b, t, g, d)), spec((l, b, t, g, d)),
        ]
        fn, flat = flatten_call(
            lambda p, tok, pos, kc, vc: M.gqa_decode(p, tok, pos, kc, vc, cfg),
            M.GQA_KEYS, gsh, extras, cfg)
        ex.export(f"{name}_gqa_decode_b{b}", fn, flat,
                  {**base_meta, "arch": "gqa", "kind": "decode", "batch": b,
                   "params": M.GQA_KEYS})

    # Context-length variants of the decode step (Fig. 4 / Table 4 measured
    # sweep): same weights, shorter cache capacity.
    if full:
        b = max(DECODE_BATCHES)
        for tctx in (128, 256):
            l, g, d = cfg.n_layers, cfg.n_kv_groups, cfg.head_dim
            extras = [
                spec((b,), I32), spec((b,), I32),
                spec((l, b, tctx, g, d)), spec((l, b, tctx, g, d)),
            ]
            fn, flat = flatten_call(
                lambda p, tok, pos, kc, vc: M.gqa_decode(p, tok, pos, kc, vc, cfg),
                M.GQA_KEYS, gsh, extras, cfg)
            ex.export(f"{name}_gqa_decode_b{b}_t{tctx}", fn, flat,
                      {**base_meta, "arch": "gqa", "kind": "decode",
                       "batch": b, "params": M.GQA_KEYS})
            r_min = min(table1_ranks)
            ash = M.mla_abs_shapes(cfg, r_min)
            extras = [
                spec((b,), I32), spec((b,), I32),
                spec((l, b, tctx, r_min)), spec((l, b, tctx, d)),
            ]
            fn, flat = flatten_call(
                lambda p, tok, pos, cc, kr: M.mla_decode(p, tok, pos, cc, kr, cfg),
                M.MLA_ABS_KEYS, ash, extras, cfg)
            ex.export(f"{name}_mla_decode_r{r_min}_b{b}_t{tctx}", fn, flat,
                      {**base_meta, "arch": "mla", "kind": "decode",
                       "rank": r_min, "batch": b, "params": M.MLA_ABS_KEYS})

    fn, flat = train_wrapper(M.gqa_forward_logits, M.GQA_KEYS, gsh, cfg,
                             TRAIN_BATCH, TRAIN_SEQ)
    ex.export(f"{name}_gqa_train", fn, flat,
              {**base_meta, "arch": "gqa", "kind": "train",
               "batch": TRAIN_BATCH, "seq": TRAIN_SEQ, "params": M.GQA_KEYS})

    # --- calibration forward ---
    fn, flat = flatten_call(
        lambda p, tok: M.gqa_calib(p, tok, cfg),
        M.GQA_KEYS, gsh, [spec((bp, t), I32)], cfg)
    ex.export(f"{name}_calib", fn, flat,
              {**base_meta, "arch": "gqa", "kind": "calib", "batch": bp,
               "params": M.GQA_KEYS})

    # --- merged/rotated analysis form (Fig. 2b) ---
    msh = M.merged_shapes(cfg)
    fn, flat = flatten_call(
        lambda p, tok: M.merged_prefill(p, tok, cfg),
        M.MERGED_KEYS, msh, [spec((bp, t), I32)], cfg)
    ex.export(f"{name}_merged_prefill", fn, flat,
              {**base_meta, "arch": "merged", "kind": "prefill", "batch": bp,
               "params": M.MERGED_KEYS})

    # --- MLA (absorbed) per rank ---
    for r in sorted(set(sweep_ranks) | set(table1_ranks), reverse=True):
        ash = M.mla_abs_shapes(cfg, r)
        fn, flat = flatten_call(
            lambda p, tok: M.mla_prefill(p, tok, cfg),
            M.MLA_ABS_KEYS, ash, [spec((bp, t), I32)], cfg)
        ex.export(f"{name}_mla_prefill_r{r}", fn, flat,
                  {**base_meta, "arch": "mla", "kind": "prefill", "rank": r,
                   "batch": bp, "params": M.MLA_ABS_KEYS})

        if r in table1_ranks:
            for b in (DECODE_BATCHES if full else [max(DECODE_BATCHES)]):
                l, d = cfg.n_layers, cfg.head_dim
                extras = [
                    spec((b,), I32), spec((b,), I32),
                    spec((l, b, t, r)), spec((l, b, t, d)),
                ]
                fn, flat = flatten_call(
                    lambda p, tok, pos, cc, kr: M.mla_decode(
                        p, tok, pos, cc, kr, cfg),
                    M.MLA_ABS_KEYS, ash, extras, cfg)
                ex.export(f"{name}_mla_decode_r{r}_b{b}", fn, flat,
                          {**base_meta, "arch": "mla", "kind": "decode",
                           "rank": r, "batch": b, "params": M.MLA_ABS_KEYS})

            tsh = M.mla_train_shapes(cfg, r)
            fn, flat = train_wrapper(M.mla_train_forward, M.MLA_TRAIN_KEYS,
                                     tsh, cfg, TRAIN_BATCH, TRAIN_SEQ)
            ex.export(f"{name}_mla_train_r{r}", fn, flat,
                      {**base_meta, "arch": "mla", "kind": "train", "rank": r,
                       "batch": TRAIN_BATCH, "seq": TRAIN_SEQ,
                       "params": M.MLA_TRAIN_KEYS})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="llama2tiny,smoltiny")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    ex = Exporter(args.out)
    for cname in args.configs.split(","):
        cfg = CONFIGS[cname]
        export_config(
            ex, cfg, TABLE1_RANKS[cname], SWEEP_RANKS[cname],
            full=(cname == "llama2tiny"),
        )

    manifest = {
        "entries": ex.entries,
        "configs": {k: v.to_dict() for k, v in CONFIGS.items()},
        "table1_ranks": TABLE1_RANKS,
        "sweep_ranks": SWEEP_RANKS,
        "param_orders": {
            "gqa": M.GQA_KEYS,
            "mla_abs": M.MLA_ABS_KEYS,
            "mla_train": M.MLA_TRAIN_KEYS,
            "merged": M.MERGED_KEYS,
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(ex.entries)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
