"""Model + export configurations — single source of truth for shapes.

The Rust side never imports this: `aot.py` serializes everything the
coordinator needs (arg order, shapes, hyper-parameters, rank tables) into
``artifacts/manifest.json``.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the byte-level GQA/MHA transformer.

    Mirrors the LLaMA-2 family structurally (RMSNorm, SwiGLU, RoPE,
    optional grouped KV heads) at a CPU-trainable scale.
    """

    name: str
    vocab: int = 256        # byte-level
    d_model: int = 256      # D
    n_heads: int = 8        # h
    n_kv_groups: int = 8    # g  (g == h -> MHA, like LLaMA-2-7B)
    head_dim: int = 32      # d  (D / h)
    n_layers: int = 4       # L
    d_ff: int = 768         # SwiGLU hidden
    max_seq: int = 512      # Tmax: prefill length and KV-cache capacity
    rope_theta: float = 10000.0

    @property
    def kv_dim(self) -> int:
        """Merged key (or value) width: g*d."""
        return self.n_kv_groups * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_per_token(self) -> int:
        """KV cache floats per token per layer in the GQA model: 2*g*d."""
        return 2 * self.kv_dim

    def mla_kv_per_token(self, r: int) -> int:
        """KV cache floats per token per layer after TransMLA: r + d_rope."""
        return r + self.head_dim

    def compression(self, r: int) -> float:
        """Fraction of the KV cache removed (paper's "-X%" notation)."""
        return 1.0 - self.mla_kv_per_token(r) / self.kv_per_token

    def to_dict(self):
        return asdict(self)


# LLaMA-2-7B analogue: full MHA (g == h).
LLAMA2TINY = ModelConfig(name="llama2tiny", n_kv_groups=8)

# SmolLM analogue: true GQA (g < h), exercises the grouped merge path.
SMOLTINY = ModelConfig(name="smoltiny", n_kv_groups=4)

CONFIGS = {c.name: c for c in (LLAMA2TINY, SMOLTINY)}

# Latent ranks exported per config. llama2tiny 2gd=512, rope head 32:
#   r=128 -> keep 160 = -68.75%   (paper row)
#   r= 32 -> keep  64 = -87.50%   (paper row)
#   r=  4 -> keep  36 = -92.97%   (paper row)
# plus extra ranks used by the Fig. 3b compression sweep.
TABLE1_RANKS = {"llama2tiny": [128, 32, 4], "smoltiny": [48, 16]}
SWEEP_RANKS = {"llama2tiny": [192, 128, 64, 32, 16, 4], "smoltiny": [48, 16]}

# Decode batch sizes exported for the serving engine.
DECODE_BATCHES = [1, 8]
PREFILL_BATCH = 8
TRAIN_BATCH = 8
TRAIN_SEQ = 128

ATTN_SCALE_NOTE = (
    "converted models keep the original 1/sqrt(d) scale so the "
    "transformation is exactly equivalence-preserving"
)
