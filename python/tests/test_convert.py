"""Conversion invariances — the paper's Appendix A/B/C/D as executable math.

These validate the reference converter against the L2 models:
  * Eq. 19 / Appendix B: RoRoPE rotation leaves logits exactly unchanged.
  * Sec. 4.1: merged single-key-head form == original GQA, exactly.
  * Appendix D: full-rank balanced joint PCA == the merged-masked model.
  * Eq. 10: absorbed form == trainable form, exactly, at any rank.
  * Appendix C Proposition 2: FreqFold joint PCA captures >= variance.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import convert_ref as C
from compile import model as M
from compile.configs import ModelConfig

CFG_MHA = ModelConfig(name="t_mha", vocab=64, d_model=64, n_heads=4,
                      n_kv_groups=4, head_dim=16, n_layers=2, d_ff=96,
                      max_seq=32)
CFG_GQA = ModelConfig(name="t_gqa", vocab=64, d_model=64, n_heads=4,
                      n_kv_groups=2, head_dim=16, n_layers=2, d_ff=96,
                      max_seq=32)


def setup(cfg, seed=0):
    p = M.init_gqa_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (2, cfg.max_seq),
                              0, cfg.vocab)
    logits, _, _ = M.gqa_prefill(p, toks, cfg)
    kp, va, qp = M.gqa_calib(p, toks, cfg)
    lyr = cfg.n_layers
    calib = tuple(
        np.asarray(a, np.float64).reshape(lyr, -1, a.shape[-1])
        for a in (kp, va, qp)
    )
    pn = {k: np.asarray(v, np.float64) for k, v in p.items()}
    return p, pn, toks, logits, calib


def as_f32(d):
    return {k: jnp.asarray(v, jnp.float32) for k, v in d.items()}


@pytest.mark.parametrize("cfg", [CFG_MHA, CFG_GQA], ids=["mha", "gqa"])
def test_merged_form_is_exact(cfg):
    _, pn, toks, logits, _ = setup(cfg)
    mp = C.merged_params_from(pn, cfg)
    lm = M.merged_prefill(as_f32(mp), toks, cfg)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [CFG_MHA, CFG_GQA], ids=["mha", "gqa"])
def test_rorope_rotation_is_orthogonal_and_exact(cfg):
    _, pn, toks, logits, calib = setup(cfg)
    k_pre = calib[0]
    qbigs = []
    for l in range(cfg.n_layers):
        qb, nf = C.rorope_rotation(k_pre[l], cfg, fold=1)
        np.testing.assert_allclose(qb @ qb.T, np.eye(cfg.kv_dim), atol=1e-9)
        # fold=1 keeps the original frequency schedule
        np.testing.assert_allclose(nf, C.merged_freqs(cfg))
        qbigs.append(qb)
    mp = C.merged_params_from(pn, cfg, q_big=qbigs)
    lm = M.merged_prefill(as_f32(mp), toks, cfg)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


def test_rorope_concentrates_energy_into_head0():
    cfg = CFG_MHA
    _, _, _, _, calib = setup(cfg)
    k = calib[0][0]
    qb, _ = C.rorope_rotation(k, cfg, fold=1)
    k_rot = k @ qb.T
    d = cfg.head_dim
    head_energy = [
        float(np.sum(k_rot[:, j * d:(j + 1) * d] ** 2))
        for j in range(cfg.n_kv_groups)
    ]
    assert head_energy[0] == max(head_energy)
    # energy must be non-increasing by construction of the PCA ordering
    # (component c of every frequency goes to head c).
    assert all(head_energy[i] >= head_energy[i + 1] - 1e-9
               for i in range(len(head_energy) - 1))


@pytest.mark.parametrize("fold", [2, 4])
def test_freqfold_proposition2(fold):
    """Prop. 2: V2 (joint PCA over folded groups, top M*..) >= V1 (separate
    per-frequency PCAs keeping the top component each)."""
    cfg = CFG_MHA
    _, _, _, _, calib = setup(cfg)
    k = calib[0][0]
    g, d = cfg.n_kv_groups, cfg.head_dim
    n_freq = d // 2
    for m in range(n_freq // fold):
        ls = list(range(m * fold, (m + 1) * fold))
        v1 = 0.0
        zs = []
        for l in ls:
            re = [C.real_dim(j, l, d) for j in range(g)]
            im = [c + 1 for c in re]
            z = np.concatenate([k[:, re], k[:, im]], axis=0)
            zs.append(z)
            w, _ = C.eigh_desc(z.T @ z)
            v1 += w[0]
        zcat = np.concatenate(zs, axis=1)
        w, _ = C.eigh_desc(zcat.T @ zcat)
        v2 = np.sum(w[:fold])
        assert v2 >= v1 - 1e-6


@pytest.mark.parametrize("cfg", [CFG_MHA, CFG_GQA], ids=["mha", "gqa"])
def test_full_rank_conversion_matches_merged_masked(cfg):
    """TransMLA at full rank == merged model with RoPE kept on head 0 only
    (the only approximation is RoPE removal, not the PCA)."""
    _, pn, toks, _, calib = setup(cfg)
    r_full = (2 * cfg.n_kv_groups - 1) * cfg.head_dim
    train, absorbed, _ = C.convert_model(pn, calib, cfg, r_full, fold=1)
    lt = M.mla_train_forward(as_f32(train), toks, cfg)

    qbigs = [C.rorope_rotation(calib[0][l], cfg, fold=1)[0]
             for l in range(cfg.n_layers)]
    mask = C.rorope_mask(cfg, keep_components=1, fold=1)
    mp = C.merged_params_from(pn, cfg, q_big=qbigs, mask=mask)
    lm = M.merged_prefill(as_f32(mp), toks, cfg)
    np.testing.assert_allclose(np.asarray(lt), np.asarray(lm),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("r", [8, 24, 48])
def test_absorb_equivalence_any_rank(r):
    """Eq. 10: absorbed == trainable logits at every rank."""
    cfg = CFG_MHA
    _, pn, toks, _, calib = setup(cfg)
    train, absorbed, _ = C.convert_model(pn, calib, cfg, r, fold=1)
    lt = M.mla_train_forward(as_f32(train), toks, cfg)
    la, _, _ = M.mla_prefill(as_f32(absorbed), toks, cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lt),
                               rtol=2e-4, atol=2e-4)


def test_bkv_alpha_balances_norms():
    cfg = CFG_MHA
    _, pn, toks, _, calib = setup(cfg)
    k_pre, v_act, _ = calib
    qb, _ = C.rorope_rotation(k_pre[0], cfg, fold=1)
    k_rot = k_pre[0] @ qb.T
    k_nope = k_rot[:, cfg.head_dim:]
    alpha = C.kv_balance_alpha(k_nope, v_act[0])
    assert alpha > 0
    kn = np.mean(np.linalg.norm(k_nope / alpha, axis=1))
    vn = np.mean(np.linalg.norm(v_act[0], axis=1))
    np.testing.assert_allclose(kn, vn, rtol=1e-6)


def test_bkv_improves_value_reconstruction():
    """The point of BKV: without balancing, PCA directions are dominated by
    the (larger-norm) keys and the value reconstruction error is worse."""
    cfg = CFG_MHA
    _, pn, toks, _, calib = setup(cfg, seed=3)
    k_pre, v_act, _ = calib
    qb, _ = C.rorope_rotation(k_pre[0], cfg, fold=1)
    k_rot = k_pre[0] @ qb.T
    d = cfg.head_dim
    # exaggerate the imbalance the paper observes
    k_nope = k_rot[:, d:] * 10.0
    v = v_act[0]
    r = 24

    def v_err(alpha):
        rb = C.joint_lowrank_basis(k_nope, v, alpha, r)
        z = np.concatenate([k_nope / alpha, v], axis=1)
        zc = z @ rb @ rb.T
        v_rec = zc[:, k_nope.shape[1]:]
        return float(np.linalg.norm(v_rec - v))

    err_bal = v_err(C.kv_balance_alpha(k_nope, v))
    err_raw = v_err(1.0)
    assert err_bal < err_raw


def test_mha2mla_mask_budget_and_structure():
    cfg = CFG_MHA
    _, pn, toks, _, calib = setup(cfg)
    k_pre, _, q_pre = calib
    kp = 2
    mask = C.mha2mla_mask(cfg, k_pre[0], q_pre[0], kp)
    g, d = cfg.n_kv_groups, cfg.head_dim
    assert mask.sum() == g * kp * 2
    # kept dims must come in (real, imag) pairs
    m2 = mask.reshape(-1, 2)
    assert np.all(m2[:, 0] == m2[:, 1])


def test_mha2mla_baseline_conversion_runs_and_absorbs():
    cfg = CFG_MHA
    _, pn, toks, _, calib = setup(cfg)
    r = 24
    train, absorbed, _ = C.convert_model(
        pn, calib, cfg, r, baseline="mha2mla", keep_pairs_per_head=2)
    lt = M.mla_train_forward(as_f32(train), toks, cfg)
    la, _, _ = M.mla_prefill(as_f32(absorbed), toks, cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lt),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(np.asarray(lt)).all()


def test_rorope_beats_mha2mla_at_equal_budget():
    """Fig. 2b headline: at the same RoPE budget, RoRoPE's rotated-and-
    concentrated removal distorts the logits less than per-head norm
    selection."""
    cfg = CFG_MHA
    p, pn, toks, logits, calib = setup(cfg, seed=7)
    k_pre, _, q_pre = calib
    g, d = cfg.n_kv_groups, cfg.head_dim

    qbigs = [C.rorope_rotation(k_pre[l], cfg, fold=1)[0]
             for l in range(cfg.n_layers)]
    mask_ro = C.rorope_mask(cfg, keep_components=1)
    mp = C.merged_params_from(pn, cfg, q_big=qbigs, mask=mask_ro)
    l_ro = M.merged_prefill(as_f32(mp), toks, cfg)

    kp = d // (2 * g)  # same number of kept pairs in total
    mask_mm = C.mha2mla_mask(cfg, k_pre[0], q_pre[0], kp)
    mp2 = C.merged_params_from(pn, cfg, mask=mask_mm)
    l_mm = M.merged_prefill(as_f32(mp2), toks, cfg)

    err_ro = float(jnp.mean((l_ro - logits) ** 2))
    err_mm = float(jnp.mean((l_mm - logits) ** 2))
    assert err_ro < err_mm


def test_compression_error_decreases_with_rank():
    cfg = CFG_MHA
    _, pn, toks, logits, calib = setup(cfg, seed=11)
    errs = []
    for r in (8, 32, 112):
        train, _, _ = C.convert_model(pn, calib, cfg, r, fold=1)
        lt = M.mla_train_forward(as_f32(train), toks, cfg)
        errs.append(float(jnp.mean((lt - logits) ** 2)))
    assert errs[0] > errs[1] > errs[2] - 1e-9
