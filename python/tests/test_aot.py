"""Manifest / artifact consistency: the ABI the Rust coordinator relies on."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_all_artifact_files_exist(manifest):
    for e in manifest["entries"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, e["file"]


def test_entry_inventory(manifest):
    names = {e["name"] for e in manifest["entries"]}
    for need in [
        "llama2tiny_gqa_prefill", "llama2tiny_gqa_decode_b1",
        "llama2tiny_gqa_decode_b8", "llama2tiny_gqa_train",
        "llama2tiny_calib", "llama2tiny_merged_prefill",
        "llama2tiny_mla_prefill_r128", "llama2tiny_mla_decode_r4_b8",
        "llama2tiny_mla_train_r32", "smoltiny_gqa_prefill",
    ]:
        assert need in names, need


def test_param_counts_match_orders(manifest):
    orders = manifest["param_orders"]
    for e in manifest["entries"]:
        n_params = len(e["params"])
        if e["kind"] == "train":
            # params*3 + step + lr + tokens
            assert len(e["inputs"]) == 3 * n_params + 3, e["name"]
            # params*3 + loss
            assert len(e["outputs"]) == 3 * n_params + 1, e["name"]
        elif e["kind"] in ("prefill", "calib"):
            assert len(e["inputs"]) == n_params + 1, e["name"]
        elif e["kind"] == "decode":
            assert len(e["inputs"]) == n_params + 4, e["name"]
            assert len(e["outputs"]) == 3, e["name"]
        if e["arch"] == "gqa":
            assert e["params"] == orders["gqa"]


def test_decode_cache_shapes_follow_rank(manifest):
    for e in manifest["entries"]:
        if e["kind"] != "decode":
            continue
        cfg = e["config"]
        b = e["batch"]
        lyr, d = cfg["n_layers"], cfg["head_dim"]
        cache_in = e["inputs"][-2:]
        # Context-length variants shrink T; both caches must agree on it
        # and it may never exceed max_seq.
        t = cache_in[0]["shape"][2]
        assert t <= cfg["max_seq"]
        assert cache_in[1]["shape"][2] == t
        if e["arch"] == "gqa":
            g = cfg["n_kv_groups"]
            assert cache_in[0]["shape"] == [lyr, b, t, g, d]
        else:
            r = e["rank"]
            assert cache_in[0]["shape"] == [lyr, b, t, r]
            assert cache_in[1]["shape"] == [lyr, b, t, d]


def test_compression_ratios_match_paper_rows(manifest):
    cfg = manifest["configs"]["llama2tiny"]
    kv = 2 * cfg["n_kv_groups"] * cfg["head_dim"]
    ratios = {
        r: 1.0 - (r + cfg["head_dim"]) / kv
        for r in manifest["table1_ranks"]["llama2tiny"]
    }
    assert abs(ratios[128] - 0.6875) < 1e-9
    assert abs(ratios[32] - 0.8750) < 1e-9
    assert abs(ratios[4] - 0.9297) < 1e-3
