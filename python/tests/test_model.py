"""L2 model behaviour: shapes, decode/prefill consistency, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import convert_ref as C
from compile import model as M
from compile.configs import ModelConfig

CFG = ModelConfig(name="t", vocab=64, d_model=64, n_heads=4, n_kv_groups=2,
                  head_dim=16, n_layers=2, d_ff=96, max_seq=32)


@pytest.fixture(scope="module")
def setup():
    p = M.init_gqa_params(jax.random.PRNGKey(0), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, CFG.max_seq),
                              0, CFG.vocab)
    return p, toks


def test_gqa_prefill_shapes(setup):
    p, toks = setup
    logits, kc, vc = M.gqa_prefill(p, toks, CFG)
    lyr, g, d, t = CFG.n_layers, CFG.n_kv_groups, CFG.head_dim, CFG.max_seq
    assert logits.shape == (2, t, CFG.vocab)
    assert kc.shape == (lyr, 2, t, g, d)
    assert vc.shape == (lyr, 2, t, g, d)
    assert bool(jnp.isfinite(logits).all())


def test_gqa_causality(setup):
    """Changing a future token must not change earlier logits."""
    p, toks = setup
    l1, _, _ = M.gqa_prefill(p, toks, CFG)
    toks2 = toks.at[:, 20].set((toks[:, 20] + 1) % CFG.vocab)
    l2, _, _ = M.gqa_prefill(p, toks2, CFG)
    np.testing.assert_allclose(np.asarray(l1[:, :20]), np.asarray(l2[:, :20]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(l1[:, 20:]), np.asarray(l2[:, 20:]))


def test_gqa_decode_matches_prefill_stepwise(setup):
    """Feed tokens one at a time through decode; logits must match the
    prefill logits at every position (the serving-correctness contract)."""
    p, toks = setup
    logits, _, _ = M.gqa_prefill(p, toks, CFG)
    lyr, g, d, t = CFG.n_layers, CFG.n_kv_groups, CFG.head_dim, CFG.max_seq
    kc = jnp.zeros((lyr, 2, t, g, d))
    vc = jnp.zeros((lyr, 2, t, g, d))
    decode = jax.jit(lambda tok, pos, kc, vc: M.gqa_decode(
        p, tok, pos, kc, vc, CFG))
    for i in range(8):
        pos = jnp.array([i, i], jnp.int32)
        lg, kc, vc = decode(toks[:, i], pos, kc, vc)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_mla_decode_matches_prefill_stepwise(setup):
    p, toks = setup
    pn = {k: np.asarray(v, np.float64) for k, v in p.items()}
    kp, va, qp = M.gqa_calib(p, toks, CFG)
    calib = tuple(np.asarray(a, np.float64).reshape(CFG.n_layers, -1,
                                                    a.shape[-1])
                  for a in (kp, va, qp))
    _, absorbed, _ = C.convert_model(pn, calib, CFG, 24, fold=1)
    aj = {k: jnp.asarray(v, jnp.float32) for k, v in absorbed.items()}
    logits, _, _ = M.mla_prefill(aj, toks, CFG)
    lyr, d, t = CFG.n_layers, CFG.head_dim, CFG.max_seq
    cc = jnp.zeros((lyr, 2, t, 24))
    kr = jnp.zeros((lyr, 2, t, d))
    decode = jax.jit(lambda tok, pos, cc, kr: M.mla_decode(
        aj, tok, pos, cc, kr, CFG))
    for i in range(8):
        pos = jnp.array([i, i], jnp.int32)
        lg, cc, kr = decode(toks[:, i], pos, cc, kr)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, i]),
                                   rtol=2e-4, atol=2e-4)


def test_rope_relative_property():
    """RoPE inner products depend only on relative distance."""
    freqs = M.default_freqs(16)
    x = jax.random.normal(jax.random.PRNGKey(0), (16,))
    y = jax.random.normal(jax.random.PRNGKey(1), (16,))

    def ip(tq, tk):
        xr = M.rope_apply(x, jnp.asarray(tq, jnp.float32), freqs)
        yr = M.rope_apply(y, jnp.asarray(tk, jnp.float32), freqs)
        return float(jnp.dot(xr, yr))

    np.testing.assert_allclose(ip(5, 3), ip(12, 10), rtol=1e-5)
    np.testing.assert_allclose(ip(7, 7), float(jnp.dot(x, y)), rtol=1e-5)


def test_rope_masked_identity_when_mask_zero():
    freqs = M.default_freqs(8)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    out = M.rope_apply_masked(x, jnp.asarray(9.0), freqs, jnp.zeros(8))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_train_step_reduces_loss(setup):
    p, _ = setup
    toks = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, 8)
    ts = jax.jit(M.make_train_step(M.gqa_forward_logits, CFG))
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in p.items()}
    losses = []
    params = p
    for i in range(12):
        params, m, v, loss = ts(params, m, v, jnp.float32(i + 1),
                                jnp.float32(3e-3), toks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_mla_train_step_keeps_rope_freqs_fixed(setup):
    p, toks = setup
    pn = {k: np.asarray(v, np.float64) for k, v in p.items()}
    kp, va, qp = M.gqa_calib(p, toks, CFG)
    calib = tuple(np.asarray(a, np.float64).reshape(CFG.n_layers, -1,
                                                    a.shape[-1])
                  for a in (kp, va, qp))
    train, _, _ = C.convert_model(pn, calib, CFG, 16, fold=1)
    tp = {k: jnp.asarray(v, jnp.float32) for k, v in train.items()}
    ts = jax.jit(M.make_train_step(M.mla_train_forward, CFG))
    m = {k: jnp.zeros_like(v) for k, v in tp.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in tp.items()}
    p2, _, _, loss = ts(tp, m, v, jnp.float32(1.0), jnp.float32(1e-3), toks)
    np.testing.assert_allclose(np.asarray(p2["rope_freqs"]),
                               np.asarray(tp["rope_freqs"]))
    assert np.isfinite(float(loss))


def test_lm_loss_uniform_is_log_vocab():
    logits = jnp.zeros((2, 16, 64))
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, 64)
    np.testing.assert_allclose(float(M.lm_loss(logits, toks)), np.log(64.0),
                               rtol=1e-6)
