"""Appendix A as executable math: GQA < MLA_Factorized < MQA.

The paper proves expressiveness by explicit construction; these tests
perform the constructions numerically:
  * A.2.1 — any GQA key/value map is an MLA_Factorized model with
    r_kv = 2gd and selector up-projections (exact reproduction);
  * A.2.2 — any MLA_Factorized attention is an MQA attention over the
    shared latent (score equality via the absorbed form);
  * strictness — a dense MLA_Factorized generates per-head keys no GQA
    of the same cache budget can produce.
"""

import numpy as np

from compile.configs import ModelConfig

CFG = ModelConfig(name="t", vocab=64, d_model=64, n_heads=4, n_kv_groups=2,
                  head_dim=16, n_layers=1, d_ff=96, max_seq=16)


def rand(rng, *shape):
    return rng.standard_normal(shape)


def test_gqa_embeds_into_mla_factorized_exactly():
    """A.2.1: W'^K = W^UK W^DKV with selector W^UK reproduces GQA keys."""
    rng = np.random.default_rng(0)
    h, g, d, dm = CFG.n_heads, CFG.n_kv_groups, CFG.head_dim, CFG.d_model
    wk = rand(rng, g * d, dm)   # GQA key proj (column convention)
    wv = rand(rng, g * d, dm)
    w_dkv = np.concatenate([wk, wv], axis=0)  # [2gd, D]
    rep = h // g
    x = rand(rng, dm)
    c = w_dkv @ x  # latent, cached: 2gd floats == GQA cache budget

    for i in range(h):
        j = i // rep
        w_uk_i = np.zeros((d, 2 * g * d))
        w_uk_i[:, j * d:(j + 1) * d] = np.eye(d)
        w_uv_i = np.zeros((d, 2 * g * d))
        w_uv_i[:, g * d + j * d:g * d + (j + 1) * d] = np.eye(d)
        k_i = w_uk_i @ c
        v_i = w_uv_i @ c
        np.testing.assert_allclose(k_i, (wk @ x)[j * d:(j + 1) * d], rtol=1e-12)
        np.testing.assert_allclose(v_i, (wv @ x)[j * d:(j + 1) * d], rtol=1e-12)


def test_mla_factorized_embeds_into_mqa_scores():
    """A.2.2: q_i^T k_i == (W_i^UK^T q_i)^T c — every head attends the
    shared latent directly (the Absorb identity)."""
    rng = np.random.default_rng(1)
    h, d, dm = CFG.n_heads, CFG.head_dim, CFG.d_model
    r = 24
    w_dkv = rand(rng, r, dm)
    x_t = rand(rng, dm)
    x_j = rand(rng, dm)
    c_j = w_dkv @ x_j
    for i in range(h):
        w_uk_i = rand(rng, d, r)
        w_q_i = rand(rng, d, dm)
        q_i = w_q_i @ x_t
        k_i = w_uk_i @ c_j
        score_mla = q_i @ k_i
        score_mqa = (w_uk_i.T @ q_i) @ c_j  # MQA over the latent
        np.testing.assert_allclose(score_mla, score_mqa, rtol=1e-10)


def test_dense_mla_exceeds_gqa_expressiveness():
    """Strictness: with h > g, a dense W^UK produces h DISTINCT per-head
    keys from the same latent; GQA can only replicate g distinct keys."""
    rng = np.random.default_rng(2)
    h, g, d, dm = CFG.n_heads, CFG.n_kv_groups, CFG.head_dim, CFG.d_model
    r = 2 * g * d
    w_dkv = rand(rng, r, dm)
    w_uk = rand(rng, h, d, r)  # dense, fully learnable
    x = rand(rng, dm)
    c = w_dkv @ x
    keys = np.stack([w_uk[i] @ c for i in range(h)])
    # all pairwise distinct
    for i in range(h):
        for j in range(i + 1, h):
            assert np.linalg.norm(keys[i] - keys[j]) > 1e-6
    # GQA structurally ties heads within a group: only g distinct keys.
    rep = h // g
    wk = rand(rng, g * d, dm)
    gqa_keys = np.stack(
        [(wk @ x)[(i // rep) * d:((i // rep) + 1) * d] for i in range(h)]
    )
    n_distinct = len({tuple(np.round(k, 9)) for k in gqa_keys})
    assert n_distinct == g


def test_rank_bound_of_score_maps():
    """A.2.3: per-head MLA score map rank <= d; the MQA form over the
    latent admits rank up to 2gd > d."""
    rng = np.random.default_rng(3)
    g, d, dm = CFG.n_kv_groups, CFG.head_dim, CFG.d_model
    r = 2 * g * d
    w_q = rand(rng, d, dm)
    w_uk = rand(rng, d, r)
    w_dkv = rand(rng, r, dm)
    m_mla = w_q.T @ w_uk @ w_dkv  # [D, D] bilinear score map
    assert np.linalg.matrix_rank(m_mla) <= d
    w_q_big = rand(rng, r, dm)  # MQA query straight into the latent
    m_mqa = w_q_big.T @ w_dkv
    assert np.linalg.matrix_rank(m_mqa) == min(r, dm)
