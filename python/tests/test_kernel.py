"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes; assert_allclose against the oracle is the
core correctness signal for the kernels that end up inside the decode HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gqa_attn import gqa_decode_attention
from compile.kernels.mla_attn import mla_absorbed_decode_attention

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


shape_strategy = st.tuples(
    st.integers(1, 4),                    # B
    st.sampled_from([1, 2, 4, 8]),        # g
    st.integers(1, 4),                    # rep = h // g
    st.sampled_from([4, 8, 16, 32]),      # d
    st.sampled_from([8, 16, 64, 128]),    # T
    st.integers(0, 10**6),                # seed
)


@given(shape_strategy)
def test_gqa_kernel_matches_ref(args):
    b, g, rep, d, t, seed = args
    h = g * rep
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, h, d))
    k = rand(rng, (b, t, g, d))
    v = rand(rng, (b, t, g, d))
    pos = jnp.asarray(rng.integers(0, t, size=b), jnp.int32)
    scale = 1.0 / np.sqrt(d)
    got = gqa_decode_attention(q, k, v, pos, scale=scale)
    want = ref.gqa_decode_attention_ref(q, k, v, pos, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


mla_strategy = st.tuples(
    st.integers(1, 4),                    # B
    st.sampled_from([1, 4, 8]),           # h
    st.sampled_from([4, 32, 128]),        # r
    st.sampled_from([8, 16, 32]),         # dr
    st.sampled_from([8, 64, 128]),        # T
    st.integers(0, 10**6),                # seed
)


@given(mla_strategy)
def test_mla_kernel_matches_ref(args):
    b, h, r, dr, t, seed = args
    rng = np.random.default_rng(seed)
    ql = rand(rng, (b, h, r))
    qr = rand(rng, (b, h, dr))
    c = rand(rng, (b, t, r))
    kr = rand(rng, (b, t, dr))
    pos = jnp.asarray(rng.integers(0, t, size=b), jnp.int32)
    scale = 1.0 / np.sqrt(dr)
    got = mla_absorbed_decode_attention(ql, qr, c, kr, pos, scale=scale)
    want = ref.mla_absorbed_decode_attention_ref(ql, qr, c, kr, pos, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_gqa_kernel_pos_zero_attends_only_first():
    """pos=0 must ignore every cache slot except index 0."""
    rng = np.random.default_rng(0)
    q = rand(rng, (1, 2, 4))
    k = rand(rng, (1, 16, 2, 4))
    v = rand(rng, (1, 16, 2, 4))
    out = gqa_decode_attention(q, k, v, jnp.array([0], jnp.int32), scale=0.5)
    np.testing.assert_allclose(
        np.asarray(out)[0], np.asarray(v)[0, 0], rtol=1e-6, atol=1e-6
    )


def test_mla_kernel_pos_zero_attends_only_first():
    rng = np.random.default_rng(0)
    ql = rand(rng, (1, 3, 8))
    qr = rand(rng, (1, 3, 4))
    c = rand(rng, (1, 16, 8))
    kr = rand(rng, (1, 16, 4))
    out = mla_absorbed_decode_attention(
        ql, qr, c, kr, jnp.array([0], jnp.int32), scale=0.5)
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(out)[0, i], np.asarray(c)[0, 0], rtol=1e-6, atol=1e-6)


def test_mla_kernel_padding_is_ignored():
    """Garbage beyond pos must not change the result."""
    rng = np.random.default_rng(1)
    ql, qr = rand(rng, (2, 4, 16)), rand(rng, (2, 4, 8))
    c, kr = rand(rng, (2, 32, 16)), rand(rng, (2, 32, 8))
    pos = jnp.array([5, 17], jnp.int32)
    base = mla_absorbed_decode_attention(ql, qr, c, kr, pos, scale=0.3)
    c2 = c.at[0, 6:].set(1e4).at[1, 18:].set(-1e4)
    kr2 = kr.at[0, 6:].set(333.0)
    got = mla_absorbed_decode_attention(ql, qr, c2, kr2, pos, scale=0.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_gqa_kernel_padding_is_ignored():
    rng = np.random.default_rng(2)
    q = rand(rng, (2, 4, 8))
    k, v = rand(rng, (2, 32, 2, 8)), rand(rng, (2, 32, 2, 8))
    pos = jnp.array([3, 30], jnp.int32)
    base = gqa_decode_attention(q, k, v, pos, scale=0.3)
    k2 = k.at[0, 4:].set(1e4)
    v2 = v.at[1, 31:].set(-77.0)
    got = gqa_decode_attention(q, k2, v2, pos, scale=0.3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mla_kernel_dtypes(dtype):
    rng = np.random.default_rng(3)
    ql = jnp.asarray(rng.standard_normal((1, 4, 16)), dtype)
    qr = jnp.asarray(rng.standard_normal((1, 4, 8)), dtype)
    c = jnp.asarray(rng.standard_normal((1, 16, 16)), dtype)
    kr = jnp.asarray(rng.standard_normal((1, 16, 8)), dtype)
    pos = jnp.array([15], jnp.int32)
    got = mla_absorbed_decode_attention(ql, qr, c, kr, pos, scale=0.25)
    want = ref.mla_absorbed_decode_attention_ref(
        ql.astype(jnp.float32), qr.astype(jnp.float32),
        c.astype(jnp.float32), kr.astype(jnp.float32), pos, 0.25)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol)


def test_softmax_rows_sum_to_one_property():
    """Indirect invariant: with constant values, output == that constant."""
    rng = np.random.default_rng(4)
    ql, qr = rand(rng, (1, 2, 8)), rand(rng, (1, 2, 4))
    c = jnp.ones((1, 16, 8)) * 3.25
    kr = rand(rng, (1, 16, 4))
    out = mla_absorbed_decode_attention(
        ql, qr, c, kr, jnp.array([9], jnp.int32), scale=0.7)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)
